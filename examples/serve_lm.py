"""Serving example: batched requests through the engine — chunked
prefill into slots, continuous batched decode, per-request sampling.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.launch.mesh import make_local_mesh
from repro.models.model import Model
from repro.serve.engine import Engine, Request


def main() -> None:
    cfg = reduced(get_config("gemma3-4b"), d_model=256, num_layers=6,
                  vocab_size=32000, sliding_window=64, prefill_chunk=32)
    mesh = make_local_mesh(2, 4)
    engine = Engine(cfg, mesh, slots=4, max_len=256)
    params = Model(cfg, mesh).init(jax.random.PRNGKey(0))
    engine.load(params)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=20 + 13 * i),
                    max_new_tokens=24,
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i in range(8)]
    t0 = time.time()
    results = engine.run_to_completion(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in results.values())
    print(f"served {len(results)} requests, {total} tokens "
          f"in {dt:.1f}s ({total/dt:.1f} tok/s on CPU)")
    for rid in sorted(results):
        print(f"  req {rid}: {results[rid][:8]}...")


if __name__ == "__main__":
    main()
