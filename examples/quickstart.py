"""PGAS quickstart — the paper's programming model in five minutes.

Mirrors pPython's hello-world: build a map (paper Fig 1), create
distributed arrays, compute locally, aggregate to the leader with the
node-aware binary-tree agg(), and redistribute between maps.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Dmap, Dmat, ones, rand, zeros
from repro.launch.mesh import make_local_mesh


def main() -> None:
    mesh = make_local_mesh(2, 4)   # 8 virtual ranks: 2 "nodes" x 4
    print(f"mesh: {dict(mesh.shape)}  ({mesh.devices.size} ranks)")

    # Fig 1: a map is (grid, distribution, processor list[, order])
    m = Dmap(grid=(4, 2), dist=(("b",), ("b",)), procs=tuple(range(8)))
    x = Dmat.from_global(jnp.arange(16 * 6, dtype=jnp.float32).reshape(16, 6),
                         m, mesh)
    y = ones((16, 6), map=m, mesh=mesh)

    # maps are orthogonal to correctness: elementwise ops stay local
    z = x + y * 2.0
    print("sum(z) =", float(z.sum()), " (serial check:",
          float((jnp.arange(96) + 2).sum()), ")")

    # the paper's agg(): two-level binary-tree gather onto the leader
    agg = jax.jit(lambda s: Dmat(s, z.dmap, z.shape, mesh).agg())(z.storage)
    print("agg == global:", bool(jnp.allclose(agg, z.to_global())))

    # transparent redistribution between any block-cyclic maps
    m2 = Dmap(grid=(2, 4), dist=(("c",), ("bc", 2)), order="F")
    z2 = z.redistribute(m2)
    print("redistribute roundtrip ok:",
          bool(jnp.allclose(z2.to_global(), z.to_global())))

    # 'turn parallelism off' by dropping the map (paper §II.A)
    serial = zeros((4, 4))
    print("map=None gives a plain array:", type(serial).__name__)


if __name__ == "__main__":
    main()
