"""Reproduce the paper's Fig 7 story on virtual devices: the serialized
'initial' broadcast vs the node-aware binary-tree broadcast vs the
native-transport baseline, across message sizes — plus the modeled
extension to pod scale.

Run:  PYTHONPATH=src python examples/collective_comparison.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comms import Communicator
from repro.core import topology
from repro.launch.mesh import make_local_mesh


def timeit(fn, x, iters=5):
    jax.block_until_ready(fn(x))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(x))
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    mesh = make_local_mesh(2, 2, pod=2)   # two "pods" of 2x2
    spec = P(tuple(mesh.axis_names))
    serial_comm = Communicator(mesh, "serial")
    tree_comm = Communicator(mesh, "tree")

    def jit_bcast(comm):
        return jax.jit(comm.wrap(comm.bcast, in_specs=(spec,),
                                 out_specs=spec))

    print(f"{'bytes/rank':>12} {'serial us':>10} {'tree us':>10} "
          f"{'speedup':>8}")
    for size in (8, 8 * 1024, 8 * 1024 * 1024):
        x = jnp.ones((8, max(size // 4, 1)), jnp.float32)
        ts = timeit(jit_bcast(serial_comm), x)
        tt = timeit(jit_bcast(tree_comm), x)
        print(f"{size:>12} {ts:>10.0f} {tt:>10.0f} {ts/tt:>7.1f}x")

    print("\nmodeled at pod scale (v5e, 256 ranks/pod):")
    for ranks in (256, 512, 768):
        nl, ng = min(ranks, 256), max(ranks // 256, 1)
        t_tree = topology.two_level_cost(nl, ng, 8 << 20, 50e9, 6.25e9, True)
        t_serial = topology.two_level_cost(nl, ng, 8 << 20, 50e9, 6.25e9,
                                           False)
        print(f"  {ranks} ranks, 8MiB: tree {t_tree*1e3:.1f}ms vs serial "
              f"{t_serial*1e3:.0f}ms ({t_serial/t_tree:.0f}x)")


if __name__ == "__main__":
    main()
