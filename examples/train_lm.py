"""End-to-end driver: train a ~56M-param LM (same family, scaled width;
pass --steps for a few hundred steps on real hardware) with the full
production loop — sharded init, microbatched train
step, pod-aware gradient exchange, async checkpoints, restart, and the
straggler watchdog.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
(takes ~90 s/step on 1 CPU core — default --steps 30 for a quick
look.)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses

from repro.configs.base import ShapeSpec, get_config, reduced
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)  # ~90 s/step on 1 CPU core
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--grad-comms", default="hier",
                    choices=("auto", "tree", "hier", "hier_int8"))
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # danube family member at width 512 (~56M params)
    cfg = reduced(get_config(args.arch),
                  d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
                  d_ff=1408, num_layers=8, vocab_size=32000,
                  sliding_window=256, microbatches=2)
    print(f"params ~= {cfg.param_count()/1e6:.0f}M  arch={cfg.name} "
          f"grad_comms={args.grad_comms}")
    shape = ShapeSpec("train", "train", seq_len=256, global_batch=16)
    mesh = make_local_mesh(2, 4)
    trainer = Trainer(cfg, shape, mesh, TrainerConfig(
        total_steps=args.steps, checkpoint_every=50, ckpt_dir=args.ckpt,
        grad_comms=args.grad_comms, log_every=10))
    out = trainer.run(resume=True)     # auto-resumes if a ckpt exists
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {len(out['history'])} steps"
          f"  (straggler flags: {out['straggler_flags']})")


if __name__ == "__main__":
    main()
