"""File-based sharded checkpointing with atomic commit and async writes.

This is where pPython's file-based-messaging heritage lives on in the
TPU adaptation (DESIGN.md §2): durable, one-sided, filesystem-mediated
state exchange — used for checkpoint/restart, elastic re-meshing, and
cross-job handoff, with exactly the paper's virtues (no extra ports or
services; security = filesystem permissions; message size bounded only
by disk).

Layout:
    <dir>/step_<N>.tmp/...      (in-progress write)
    <dir>/step_<N>/manifest.json + leaf_<i>.npy [+ .shard_<host>]
    <dir>/LATEST                (atomic pointer file)

Writes go leaf-by-leaf to the .tmp directory and are committed by a
single atomic rename + LATEST update, so a crash mid-write can never
leave a checkpoint that restore() would consider valid — the paper's
one-sided-send discipline applied to state files.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class _Tagged:
    """Host snapshot of a leaf: numpy buffer + original dtype tag."""
    __slots__ = ("arr", "tag")

    def __init__(self, arr: np.ndarray, tag: str):
        self.arr, self.tag = arr, tag


def _to_host(leaf) -> Tuple[np.ndarray, str]:
    """Host numpy copy + dtype tag (bf16 stored as f32 on disk)."""
    if isinstance(leaf, _Tagged):
        return leaf.arr, leaf.tag
    if isinstance(leaf, np.ndarray):
        return leaf, str(leaf.dtype)
    x = jax.numpy.asarray(leaf)
    if str(x.dtype) == "bfloat16":
        return np.asarray(jax.device_get(x.astype(jax.numpy.float32))), \
            "bfloat16"
    return np.asarray(jax.device_get(x)), str(x.dtype)


def save(ckpt_dir: str, step: int, tree, *, process_index: int = 0,
         keep_last: int = 3) -> str:
    """Synchronous sharded save with atomic commit."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + f".tmp{process_index}"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _leaf_paths(tree)
    manifest = {"step": step, "n_leaves": len(leaves),
                "treedef": str(treedef),
                "dtypes": [], "shapes": []}
    for i, leaf in enumerate(leaves):
        arr, tag = _to_host(leaf)
        manifest["dtypes"].append(tag)
        manifest["shapes"].append(list(arr.shape))
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic commit
    latest_tmp = os.path.join(ckpt_dir, f".LATEST.tmp{process_index}")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
    os.replace(latest_tmp, os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and "tmp" not in name:
            try:
                out.append(int(name.split("_")[1]))
            except (IndexError, ValueError):
                pass
    return out


def _is_complete(step_dir: str) -> bool:
    """A step dir restore() would actually succeed on: parseable
    manifest and every declared leaf file present and non-empty.  The
    atomic-rename commit makes torn writes unlikely, but disk-full
    truncation or a crashed copy of a checkpoint tree can still leave a
    directory that LOOKS committed — failover must skip it, not die."""
    try:
        with open(os.path.join(step_dir, "manifest.json")) as f:
            manifest = json.load(f)
        n = int(manifest["n_leaves"])
    except (OSError, ValueError, KeyError):
        return False
    for i in range(n):
        p = os.path.join(step_dir, f"leaf_{i}.npy")
        try:
            if os.path.getsize(p) == 0:
                return False
        except OSError:
            return False
    return True


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest COMPLETE step: the LATEST pointer is trusted first, but a
    missing/corrupt target falls back to the newest step dir that
    passes the completeness check (see ``_is_complete``)."""
    candidates = sorted(all_steps(ckpt_dir), reverse=True)
    path = os.path.join(ckpt_dir, "LATEST")
    if os.path.exists(path):
        try:
            with open(path) as f:
                s = int(f.read().strip())
            candidates = [s] + [x for x in candidates if x != s]
        except ValueError:
            pass
    for s in candidates:
        if _is_complete(os.path.join(ckpt_dir, f"step_{s:08d}")):
            return s
    return None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; device_put with
    ``shardings`` when given (the elastic-remesh path passes the NEW
    mesh's shardings — redistribution is just a resharded load)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _leaf_paths(like_tree)
    assert manifest["n_leaves"] == len(leaves), "checkpoint/model mismatch"
    out_leaves = []
    sh_leaves = jax.tree.flatten(shardings)[0] if shardings is not None \
        else [None] * len(leaves)
    for i, (leaf, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = np.load(os.path.join(d, f"leaf_{i}.npy"))
        if manifest["dtypes"][i] == "bfloat16":
            arr = jax.numpy.asarray(arr).astype(jax.numpy.bfloat16)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        else:
            arr = jax.numpy.asarray(arr)
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)
        out_leaves.append(arr)
    return treedef.unflatten(out_leaves)


class AsyncCheckpointer:
    """Background writer: snapshot to host, save on a worker thread.
    ``wait()`` joins the in-flight write (called before exit / failover)."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save_async(self, step: int, tree) -> None:
        """Snapshot to host *synchronously* (the train step donates its
        inputs, so device buffers may be gone by the time the worker
        runs), then write on the worker thread."""
        self.wait()
        snap = jax.tree.map(lambda x: _Tagged(*_to_host(x)), tree)

        def work():
            save(self.ckpt_dir, step, snap, keep_last=self.keep_last)
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
