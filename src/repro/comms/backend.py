"""DEPRECATED shim — the string-factory Backend API, kept one release.

The comms layer is now the mesh-bound :class:`repro.comms.Communicator`
(see communicator.py / README.md); algorithms live in the transport
registry (transports.py).  ``for_name`` and the ``Backend`` alias below
delegate there and will be removed in the next release.
"""
from __future__ import annotations

import warnings
from typing import Optional, Sequence

from repro.comms.topology import Topology
from repro.comms.transports import (Transport, available_transports,
                                    get_transport)

Backend = Transport     # old name for isinstance checks in downstream code


def _topology(pod_axis: Optional[str], in_axes: Sequence[str]) -> Topology:
    # legacy callers pass no mesh; sizes are only needed by ops that the
    # legacy surface (allreduce/bcast/agg) resolves inside shard_map, so
    # a sizeless placeholder is sound for them — but not for the new ops.
    axes = ((pod_axis,) if pod_axis else ()) + tuple(in_axes)
    return Topology(pod_axis=pod_axis, in_axes=tuple(in_axes),
                    axis_sizes=(0,) * len(axes))


def for_name(name: str, pod_axis: Optional[str], in_axes: Sequence[str]
             ) -> Transport:
    """DEPRECATED: use ``Communicator(mesh, spec)`` instead."""
    warnings.warn(
        "repro.comms.backend.for_name is deprecated; construct a "
        "repro.comms.Communicator(mesh, spec=name) instead",
        DeprecationWarning, stacklevel=2)
    if name not in available_transports():
        raise ValueError(f"unknown comms backend {name!r}")
    return get_transport(name, _topology(pod_axis, in_axes))
