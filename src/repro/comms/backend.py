"""Layered messaging: the pPython architecture point that "any other
communication library could be substituted for PythonMPI".

``Backend`` is the interface the PGAS layer and the trainer's gradient
exchange program against.  Two implementations:

* ``NativeCollectives`` — XLA's own collectives (psum / all_gather /
  psum_scatter).  This is the platform-native transport: the analogue of
  the paper's mpi4py-over-OpenMPI-RoCE baseline.
* ``TreeMessaging``    — explicit point-to-point `ppermute` rounds
  organized by the paper's node-aware binary-tree schedules (PythonMPI
  analogue: the transport *we* schedule, not the vendor library).

Both are pure functions usable inside `shard_map`; `for_name` picks one
from a CLI flag.
"""
from __future__ import annotations

import abc
from typing import Optional, Sequence

import jax
from jax import lax

from repro.core import collectives as coll

Array = jax.Array


class Backend(abc.ABC):
    """Collective interface over (pod_axis, in_axes) hierarchy levels."""

    def __init__(self, pod_axis: Optional[str], in_axes: Sequence[str]):
        self.pod_axis = pod_axis
        self.in_axes = tuple(in_axes)

    @abc.abstractmethod
    def allreduce(self, x: Array) -> Array:
        ...

    @abc.abstractmethod
    def bcast(self, x: Array, root: int = 0) -> Array:
        ...

    @abc.abstractmethod
    def agg(self, x: Array, root: int = 0) -> Array:
        """Concat-gather the per-rank block onto the leader."""
        ...

    @property
    def axes(self):
        return ((self.pod_axis,) if self.pod_axis else ()) + self.in_axes


class NativeCollectives(Backend):
    """XLA-native (the 'mpi4py/RoCE' baseline)."""

    def allreduce(self, x):
        return lax.psum(x, self.axes)

    def bcast(self, x, root: int = 0):
        # native broadcast = all-gather + select root's block; XLA has no
        # bcast primitive, this is what GSPMD emits for replication
        flat = x.reshape(-1)
        full = flat
        for a in reversed(self.in_axes):
            full = lax.all_gather(full, a, axis=0, tiled=True)
        if self.pod_axis:
            full = lax.all_gather(full, self.pod_axis, axis=0, tiled=True)
        return full[: flat.shape[0] * 0 + flat.shape[0]].reshape(x.shape) \
            if root == 0 else full.reshape((-1,) + x.shape)[root]

    def agg(self, x, root: int = 0):
        flat = x.reshape(-1)
        full = flat
        for a in reversed(self.in_axes):
            full = lax.all_gather(full, a, axis=0, tiled=True)
        if self.pod_axis:
            full = lax.all_gather(full, self.pod_axis, axis=0, tiled=True)
        me = _linear_rank(self.pod_axis, self.in_axes)
        import jax.numpy as jnp
        return jnp.where(me == root, full, jnp.zeros_like(full))


class TreeMessaging(Backend):
    """Paper-faithful node-aware binary-tree transport (PythonMPI analogue)."""

    def allreduce(self, x):
        return coll.tree_allreduce_local(x, pod_axis=self.pod_axis,
                                         in_axes=self.in_axes)

    def bcast(self, x, root: int = 0):
        return coll.two_level_bcast(x, pod_axis=self.pod_axis,
                                    in_axes=self.in_axes, tree=True,
                                    root=root)

    def agg(self, x, root: int = 0):
        return coll.two_level_agg(x, pod_axis=self.pod_axis,
                                  in_axes=self.in_axes, root=root)


class SerialMessaging(TreeMessaging):
    """The paper's *initial* (pre-optimization) serialized broadcast —
    kept for the Fig 7 comparison."""

    def bcast(self, x, root: int = 0):
        return coll.two_level_bcast(x, pod_axis=self.pod_axis,
                                    in_axes=self.in_axes, tree=False,
                                    root=root)


class HierCollectives(Backend):
    """Beyond-paper: reduce-scatter-based hierarchical exchange with
    optional int8 cross-pod compression."""

    def __init__(self, pod_axis, in_axes, compress: Optional[str] = None):
        super().__init__(pod_axis, in_axes)
        self.compress = compress

    def allreduce(self, x):
        return coll.hier_allreduce_local(x, pod_axis=self.pod_axis,
                                         in_axes=self.in_axes,
                                         compress=self.compress)

    def bcast(self, x, root: int = 0):
        return coll.two_level_bcast(x, pod_axis=self.pod_axis,
                                    in_axes=self.in_axes, tree=True,
                                    root=root)

    def agg(self, x, root: int = 0):
        return coll.two_level_agg(x, pod_axis=self.pod_axis,
                                  in_axes=self.in_axes, root=root)


def _linear_rank(pod_axis, in_axes):
    import jax.numpy as jnp
    me = jnp.zeros((), jnp.int32)
    for a in ((pod_axis,) if pod_axis else ()) + tuple(in_axes):
        me = me * lax.axis_size(a) + lax.axis_index(a)
    return me


def for_name(name: str, pod_axis: Optional[str], in_axes: Sequence[str]
             ) -> Backend:
    if name == "native":
        return NativeCollectives(pod_axis, in_axes)
    if name == "tree":
        return TreeMessaging(pod_axis, in_axes)
    if name == "serial":
        return SerialMessaging(pod_axis, in_axes)
    if name == "hier":
        return HierCollectives(pod_axis, in_axes)
    if name == "hier_int8":
        return HierCollectives(pod_axis, in_axes, compress="int8")
    raise ValueError(f"unknown comms backend {name!r}")
