"""Deterministic fault injection for the comms layer.

pPython targets commodity clusters where delayed, dropped, and corrupted
messages — and outright node loss — are operating conditions, not
exceptions.  This module makes those conditions *reproducible*: a
:class:`FaultPlan` is a seeded schedule of

  * op-level faults, applied by :class:`ChaosTransport` (a wrapper
    around any registered transport) at trace time — injected message
    delays, drops that force a retry, and payload bit-flips that fail
    the (modeled) integrity check and are retransmitted, each retry
    paying an exponential-backoff penalty; and
  * host-level events (simulated device loss / capacity restore),
    consumed by the training loop between steps (see
    ``repro.train.recovery``).

The schedule is a pure function of ``(seed, op label, op sequence
number)`` via crc32, so two processes arming the same plan inject the
same faults in the same places — which is what lets the chaos test
assert that a faulted run reproduces the fault-free loss trajectory.

Faults are decided at *trace* time and unrolled into the compiled
program: the retried exchanges are real scheduled collectives (kept
alive through ``lax.optimization_barrier`` so XLA cannot elide the
wasted work) and the delays are real dependent compute.  Detection is
modeled — the injector knows which attempt it broke — but the recovery
semantics (retry, exponential backoff, value-exactness of the final
attempt) are the production path.

Arming is process-global and captured by each ``Communicator`` at
construction: ``maybe_wrap`` returns the transport *unchanged* when no
plan is armed (or the plan carries no op faults), so the disarmed path
has literally zero overhead.
"""
from __future__ import annotations

import contextlib
import dataclasses
import zlib
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.comms.transports import Transport

Array = jax.Array

LOSE = "lose"
RESTORE = "restore"


@dataclasses.dataclass(frozen=True)
class HostEvent:
    """A device-population change at a training step boundary.

    ``kind`` is ``"lose"`` (devices fail; the run must shrink and
    restore from the last checkpoint — the failed devices' live state is
    gone) or ``"restore"`` (capacity returns; the run may grow *live*,
    redistributing the survivors' current state with no checkpoint
    round-trip).  ``n_devices`` is the device count AFTER the event.
    """

    step: int
    kind: str
    n_devices: int

    def __post_init__(self):
        if self.kind not in (LOSE, RESTORE):
            raise ValueError(f"kind must be {LOSE!r} or {RESTORE!r}, "
                             f"got {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, deterministic schedule of injected faults.

    Rates are per collective-op-per-leaf probabilities in [0, 1].  A
    dropped or bit-flipped attempt is retried (up to ``max_attempts``
    total tries) with an exponential backoff of ``backoff_iters * 2**k``
    spin iterations before retry ``k``; an injected delay costs
    ``delay_iters`` spin iterations.  ``events`` is the host-level
    device-loss/restore schedule.
    """

    seed: int = 0
    delay_rate: float = 0.0
    drop_rate: float = 0.0
    bitflip_rate: float = 0.0
    max_attempts: int = 4
    delay_iters: int = 256
    backoff_iters: int = 64
    events: Tuple[HostEvent, ...] = ()

    def __post_init__(self):
        for name in ("delay_rate", "drop_rate", "bitflip_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} not in [0, 1]")
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        object.__setattr__(self, "events",
                           tuple(sorted(self.events, key=lambda e: e.step)))

    # ------------------------------------------------------- op schedule
    def _u(self, label: str, seq: int, salt: str) -> float:
        """Uniform [0, 1) hash of (seed, label, seq, salt) — stable
        across processes/runs (crc32, not Python's salted hash)."""
        key = f"{self.seed}:{label}:{seq}:{salt}".encode()
        return zlib.crc32(key) / 2 ** 32

    def op_faults(self, label: str, seq: int) -> Tuple[bool, Tuple[str, ...]]:
        """(delay?, failed-attempt kinds) for op number ``seq``."""
        delay = self._u(label, seq, "delay") < self.delay_rate
        failures: List[str] = []
        if self._u(label, seq, "drop") < self.drop_rate:
            failures.append("drop")
        if self._u(label, seq, "flip") < self.bitflip_rate:
            failures.append("bitflip")
        return delay, tuple(failures[: self.max_attempts - 1])

    @property
    def has_op_faults(self) -> bool:
        return (self.delay_rate > 0 or self.drop_rate > 0
                or self.bitflip_rate > 0)


# ---------------------------------------------------------------------------
# process-global arming
# ---------------------------------------------------------------------------

_STATE = {"plan": None, "seq": 0, "log": [], "consumed": set()}


def arm(plan: FaultPlan) -> None:
    """Arm ``plan`` process-wide.  Communicators built while armed wrap
    their transports; the trainer consults ``host_event`` each step."""
    _STATE["plan"] = plan
    _STATE["seq"] = 0
    _STATE["log"] = []
    _STATE["consumed"] = set()


def disarm() -> None:
    _STATE["plan"] = None


def active_plan() -> Optional[FaultPlan]:
    return _STATE["plan"]


@contextlib.contextmanager
def armed(plan: FaultPlan):
    arm(plan)
    try:
        yield plan
    finally:
        disarm()


def injection_log() -> Tuple[dict, ...]:
    """What the armed plan has injected so far (host-side record,
    appended at trace time): dicts of {op, seq, delay, failures}."""
    return tuple(_STATE["log"])


def host_event(step: int) -> Optional[HostEvent]:
    """The unconsumed host event scheduled for ``step``, if any.  Events
    are consumed explicitly (``consume``) so a post-recovery replay of
    the same step numbers does not re-fire them."""
    plan = _STATE["plan"]
    if plan is None:
        return None
    for ev in plan.events:
        if ev.step == step and (ev.step, ev.kind) not in _STATE["consumed"]:
            return ev
    return None


def consume(ev: HostEvent) -> None:
    _STATE["consumed"].add((ev.step, ev.kind))


# ---------------------------------------------------------------------------
# the chaos transport wrapper
# ---------------------------------------------------------------------------


def _spin(x: Array, iters: int) -> Array:
    """Dependent busy-work: a chained transcendental loop seeded from
    ``x`` whose result is tied back into ``x`` through an optimization
    barrier, so XLA can neither start it early nor elide it — the
    traced analogue of a link stall of ``iters`` ticks."""
    if iters <= 0:
        return x
    seed = lax.convert_element_type(jnp.reshape(x, (-1,))[0], jnp.float32)
    v = jnp.full((32,), 0.5, jnp.float32) + 1e-6 * seed

    def body(_, a):
        return jnp.sin(a) + 1e-6

    v = lax.fori_loop(0, iters, body, v)
    x, _ = lax.optimization_barrier((x, v))
    return x


def _corrupt(x: Array, kind: str, seq: int) -> Array:
    """The payload of a failed attempt.  ``drop`` models a lost message
    (the receiver sees zeros — nothing arrived before the timeout);
    ``bitflip`` models wire corruption (one flipped mantissa bit in one
    element, caught by the modeled integrity check)."""
    if kind == "drop":
        return jnp.zeros_like(x)
    flat = x.reshape(-1)
    i = seq % flat.shape[0]
    if x.dtype == jnp.float32:
        bits = lax.bitcast_convert_type(flat[i], jnp.int32)
        bad = lax.bitcast_convert_type(bits ^ jnp.int32(1 << 12), jnp.float32)
    else:  # non-f32 payloads: negate one element (still a detectable hit)
        bad = -flat[i]
    return flat.at[i].set(bad).reshape(x.shape)


class ChaosTransport(Transport):
    """Wrap any transport with the armed plan's op-level faults.

    Every data op becomes: [optional delay] -> for each scheduled failed
    attempt: run the op on a corrupted payload, discard the result (but
    keep the work, ordered, via an optimization barrier), pay the
    exponential backoff -> run the final, clean attempt.  The final
    value is bit-exact with the unwrapped transport — what retries cost
    is time, never correctness.
    """

    def __init__(self, inner: Transport, plan: FaultPlan):
        self.inner = inner
        self.plan = plan
        self.topo = inner.topo
        self.name = f"chaos({inner.name})"

    # --------------------------------------------------------- machinery
    def _chaos(self, label: str, x: Array, call) -> Array:
        seq = _STATE["seq"]
        _STATE["seq"] += 1
        delay, failures = self.plan.op_faults(label, seq)
        if delay or failures:
            _STATE["log"].append({"op": label, "seq": seq, "delay": delay,
                                  "failures": failures})
        if delay:
            x = _spin(x, self.plan.delay_iters)
        for k, kind in enumerate(failures):
            wasted = call(_corrupt(x, kind, seq))
            x, _ = lax.optimization_barrier((x, wasted))
            x = _spin(x, self.plan.backoff_iters << k)
        return call(x)

    # ------------------------------------------------------------- ops
    def allreduce(self, x):
        return self._chaos("allreduce", x, self.inner.allreduce)

    def bcast(self, x, root: int = 0):
        return self._chaos("bcast", x, lambda v: self.inner.bcast(v, root))

    def agg(self, x, root: int = 0):
        return self._chaos("agg", x, lambda v: self.inner.agg(v, root))

    def allgather(self, x):
        return self._chaos("allgather", x, self.inner.allgather)

    def scatter(self, x, root: int = 0):
        return self._chaos("scatter", x,
                           lambda v: self.inner.scatter(v, root))

    def reduce_scatter(self, x):
        return self._chaos("reduce_scatter", x, self.inner.reduce_scatter)

    def alltoall(self, x):
        return self._chaos("alltoall", x, self.inner.alltoall)

    def alltoallv(self, x, counts):
        return self._chaos("alltoallv", x,
                           lambda v: self.inner.alltoallv(v, counts))


def maybe_wrap(transport: Transport,
               plan: Optional[FaultPlan]) -> Transport:
    """Wrap ``transport`` under ``plan``'s op faults; the disarmed (or
    op-fault-free) path returns the transport object unchanged — zero
    wrapper overhead unless chaos is actually requested."""
    if plan is None or not plan.has_op_faults:
        return transport
    return ChaosTransport(transport, plan)
