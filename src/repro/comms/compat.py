"""Version-portable shims for the handful of jax APIs the comms layer
builds on.

jax moved ``shard_map`` from ``jax.experimental.shard_map`` to the
top-level namespace and renamed ``check_rep`` to ``check_vma`` along the
way; ``lax.axis_size`` is similarly recent.  Every module in this repo
goes through the helpers below instead of importing either spelling
directly, so a jax upgrade (or downgrade) is a one-file change.

Partial-manual emulation: on the 0.4.x lineage, *partial*-manual
shard_maps (some mesh axes left to GSPMD — the trainer's gradient
exchange keeps the model axis automatic) cannot lower ``axis_index`` /
``ppermute`` / ``all_gather`` / ``psum_scatter`` over the manual axes
(PartitionId errors or partitioner CHECK-crashes); only ``psum``-family
reductions survive.  ``Communicator.wrap`` therefore threads a
data-driven rank token and enters the emulation context below, under
which the scheduled primitives are rewritten onto masked ``psum`` —
numerically identical, so explicit comm algorithms keep working under
partial-manual maps; fully-manual maps (the benchmarks) always use the
real primitives.
"""
from __future__ import annotations

import contextvars
import inspect
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

try:                                        # jax >= 0.5
    from jax import shard_map as _shard_map
except ImportError:                         # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = set(inspect.signature(_shard_map).parameters)
_CHECK_KW = "check_vma" if "check_vma" in _PARAMS else "check_rep"
# partial-manual spelling: new API takes the *manual* axes (axis_names=),
# the experimental API takes the complementary *auto* set (auto=).
_MANUAL_KW = "axis_names" if "axis_names" in _PARAMS else "auto"

# the experimental-API lineage is the one that cannot lower scheduled
# primitives inside partial-manual regions
PARTIAL_MANUAL_NEEDS_EMULATION = _MANUAL_KW == "auto"


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              manual_axes: Optional[Sequence[str]] = None,
              check: bool = False) -> Callable:
    """`shard_map` under any jax version.

    ``manual_axes`` — axes mapped manually (the body sees per-shard
    blocks and may use collectives over them); every other mesh axis
    stays automatic (GSPMD).  None means fully manual.  Partial-manual
    maps require the call to happen under ``jax.jit``.
    """
    kwargs = {_CHECK_KW: check}
    if manual_axes is not None:
        manual = frozenset(manual_axes)
        rest = frozenset(mesh.axis_names) - manual
        if rest:
            kwargs[_MANUAL_KW] = (manual if _MANUAL_KW == "axis_names"
                                  else rest)
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def axis_size(axis) -> int:
    """Static size of a (possibly composite) mapped axis, inside
    shard_map.  ``lax.psum(1, axis)`` constant-folds to the size on every
    jax version; ``lax.axis_size`` only exists on recent ones."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


# ---------------------------------------------------------------------------
# partial-manual emulation context (see module docstring)
# ---------------------------------------------------------------------------

_EMU: contextvars.ContextVar = contextvars.ContextVar(
    "comms_partial_manual_ctx", default=None)

# Wire-compression context (set by repro.comms.compression.compressing):
# when active, the five wire primitives below hand in-scope floating
# payloads to the handler, which quantizes, re-enters these primitives
# with integer payloads + scales, and dequantizes.  compat never imports
# compression — the dependency points one way.
_COMPRESS: contextvars.ContextVar = contextvars.ContextVar(
    "comms_wire_compression", default=None)


def enter_partial_manual(rank, axes: Sequence[str], sizes: Sequence[int]):
    """Activate emulation for the duration of one shard_map body trace.
    ``rank`` is the traced linear rank (C-order over ``axes``), threaded
    in as data because ``axis_index`` itself cannot lower."""
    return _EMU.set({"rank": rank, "axes": tuple(axes),
                     "sizes": tuple(sizes)})


def exit_partial_manual(token) -> None:
    _EMU.reset(token)


def _coord(ctx, axis):
    """Traced coordinate along one named axis (or linear index over a
    tuple of axes), derived from the rank token."""
    axes, sizes = ctx["axes"], ctx["sizes"]
    if isinstance(axis, (tuple, list)):
        idx = jnp.zeros((), jnp.int32)
        for a in axis:
            idx = idx * sizes[axes.index(a)] + _coord(ctx, a)
        return idx
    pos = axes.index(axis)
    stride = 1
    for s in sizes[pos + 1:]:
        stride *= s
    return (ctx["rank"] // stride) % sizes[pos]


def axis_index(axis):
    """Linear index along a (possibly composite) mapped axis — C-order
    over the named axes, matching the mesh's rank layout."""
    ctx = _EMU.get()
    if ctx is None:
        return lax.axis_index(axis)
    return _coord(ctx, axis)


def psum(x, axis):
    """``lax.psum`` that survives partial-manual regions: under
    emulation, the operand is first tied to the rank token (a no-op
    ``where``), anchoring its sharding inside the manual subgroup —
    without this, the 0.4.x partitioner CHECK-fails on operands whose
    sharding it attributes to the auto region."""
    c = _COMPRESS.get()
    if c is not None and c.applies(axis, x):
        return c.psum(x, axis)
    ctx = _EMU.get()
    if ctx is not None:
        x = jnp.where(ctx["rank"] >= 0, x, jnp.zeros_like(x))
    return lax.psum(x, axis)


def ppermute(x, axis, perm):
    """`lax.ppermute`, or — under emulation — one masked-psum round per
    (src, dst) pair: dst receives src's payload, non-destinations get
    zeros (exactly ppermute's semantics)."""
    c = _COMPRESS.get()
    if c is not None and c.applies(axis, x):
        return c.ppermute(x, axis, perm)
    ctx = _EMU.get()
    if ctx is None:
        return lax.ppermute(x, axis, perm)
    me = _coord(ctx, axis)
    out = jnp.zeros_like(x)
    for s, d in perm:
        contrib = lax.psum(jnp.where(me == s, x, jnp.zeros_like(x)), axis)
        out = out + jnp.where(me == d, contrib, jnp.zeros_like(x))
    return out


def all_gather_tiled(x, axis):
    """Tiled concat-gather of a flat per-rank block along ``axis`` —
    emulated as scatter-into-zeros + psum when required."""
    c = _COMPRESS.get()
    if c is not None and c.applies(axis, x):
        return c.all_gather(x, axis)
    ctx = _EMU.get()
    if ctx is None:
        return lax.all_gather(x, axis, axis=0, tiled=True)
    n = axis_size(axis)
    me = _coord(ctx, axis)
    buf = jnp.zeros((n * x.shape[0],) + x.shape[1:], x.dtype)
    buf = lax.dynamic_update_slice(
        buf, x, (me * x.shape[0],) + (0,) * (x.ndim - 1))
    return lax.psum(buf, axis)


def all_to_all_blocks(x, axis, dim=0):
    """Single-axis ``lax.all_to_all`` with split and concat on the same
    dim: ``x`` has one block per destination along ``dim`` (size n =
    ranks on ``axis``); the result holds one block per *source* (block s
    = rank s's block addressed to this rank).  Emulated as full
    all-gather + source-column selection when required."""
    c = _COMPRESS.get()
    if c is not None and c.applies(axis, x):
        return c.all_to_all(x, axis, dim)
    ctx = _EMU.get()
    if ctx is None:
        return lax.all_to_all(x, axis, dim, dim, tiled=False)
    me = _coord(ctx, axis)
    full = all_gather_tiled(x.reshape(-1), axis).reshape((-1,) + x.shape)
    col = lax.dynamic_index_in_dim(full, me, axis=1 + dim, keepdims=False)
    return jnp.moveaxis(col, 0, dim)


def psum_scatter_blocks(x, axis):
    """``lax.psum_scatter`` of ``x`` shaped (n_ranks_along_axis, blk):
    global sum, each rank keeping its own block — emulated as full psum +
    dynamic row slice when required."""
    c = _COMPRESS.get()
    if c is not None and c.applies(axis, x):
        return c.psum_scatter(x, axis)
    ctx = _EMU.get()
    if ctx is None:
        return lax.psum_scatter(x, axis, scatter_dimension=0, tiled=False)
    me = _coord(ctx, axis)
    full = lax.psum(x, axis)
    return lax.dynamic_slice(
        full, (me,) + (0,) * (x.ndim - 1), (1,) + x.shape[1:]
    ).reshape(x.shape[1:])
