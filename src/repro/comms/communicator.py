"""The mesh-bound Communicator: the full PythonMPI surface in one object.

The paper's PGAS layer programs against a tiny messaging API (SendMsg /
RecvMsg / agg / bcast / barrier) precisely so "any other communication
library could be substituted".  ``Communicator`` is that API here:
constructed once from a mesh (hierarchy derived in one place by
``Topology.from_mesh``), it exposes

  in-shard_map ops   send / recv / sendrecv / barrier / bcast / agg /
                     scatter / allreduce / reduce_scatter / allgather /
                     alltoall / alltoallv
  jit-level entry    comm.run(fn, *args) / comm.wrap(fn)  — so callers
                     never hand-roll their own ``shard_map``

with per-op algorithm selection via ``CommSpec`` and the transport
registry (native / tree / serial / hier / hier_int8), plus optional wire
compression (``CommSpec.compression`` wraps every transport in a
``CompressedTransport``) and error-feedback allreduce.  All data ops are
pytree-aware.  See repro/comms/README.md for the paper-function mapping.
"""
from __future__ import annotations

import dataclasses
from math import prod
from typing import Any, Callable, Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.comms import compat, faults
from repro.comms import compression as compression_lib
from repro.comms.compression import CompressionSpec
from repro.comms.topology import Topology
from repro.comms.transports import (Transport, available_transports,
                                    get_transport)

Array = jax.Array

_OPS = ("allreduce", "bcast", "agg", "reduce_scatter", "allgather",
        "scatter", "alltoall")


@dataclasses.dataclass(frozen=True)
class CommSpec:
    """Per-op transport selection (names from the transport registry).

    ``overlap`` is a scheduling hint, not a transport: consumers that can
    pipeline (the train-step gradient exchange) issue each collective one
    slot *behind* the compute that produced its operand, so the exchange
    of slot *i* is in flight while slot *i+1* computes.  Transports are
    oblivious — the same algorithms run either way.

    ``compression`` composes a :class:`CompressionSpec` with every op's
    transport (``CompressedTransport``); its ``error_feedback`` flag is,
    like ``overlap``, a consumer hint — ``allreduce_ef`` and the train
    step act on it, transports are oblivious.
    """

    allreduce: str = "native"
    bcast: str = "native"
    agg: str = "native"
    reduce_scatter: str = "native"
    allgather: str = "native"
    scatter: str = "native"
    alltoall: str = "native"            # also drives alltoallv
    overlap: bool = False               # pipeline collectives behind compute
    compression: Optional[CompressionSpec] = None

    @classmethod
    def from_flag(cls, flag: str) -> "CommSpec":
        """Map a CLI-style algorithm flag (--grad-comms) to a spec.

        Grammar: ``<transport>[_<dtype>][_all][_ef][_overlap]`` —
        ``<transport>`` is any registered name, ``<dtype>`` one of
        int8/fp8/int4 (wire compression, cross-pod scope by default),
        ``_all`` widens compression to every leg, ``_ef`` enables
        error-feedback accumulation, ``_overlap`` the pipelined
        schedule.  Unknown combinations raise ``ValueError`` at parse
        time (not deep in tracing).  'auto' (GSPMD, no explicit comms)
        must be handled by the caller *before* building a Communicator.
        """
        if flag == "auto":
            raise ValueError("grad_comms='auto' means GSPMD handles the "
                             "exchange; no Communicator is involved")
        names = available_transports()

        def fail():
            raise ValueError(
                f"unknown comms flag {flag!r}; expected "
                f"<transport>[_<dtype>][_all][_ef][_overlap] with "
                f"transport in {sorted(names)} and dtype in "
                f"{list(compression_lib.DTYPES)}")

        rest, overlap = flag, False
        if rest.endswith("_overlap"):
            rest, overlap = rest[:-len("_overlap")], True
        ef = False
        if rest.endswith("_ef"):
            rest, ef = rest[:-len("_ef")], True
        scope = "cross-pod"
        if rest.endswith("_all"):
            rest, scope = rest[:-len("_all")], "all"

        cspec: Optional[CompressionSpec] = None
        if rest in names:
            base = rest
            if base == "hier_int8" and (ef or scope == "all"):
                # modifiers need an explicit spec; decompose the alias
                base = "hier"
                cspec = dataclasses.replace(compression_lib.LEGACY_INT8,
                                            error_feedback=ef, scope=scope)
            elif ef or scope == "all":
                fail()      # _ef/_all only modify a compressed mode
        else:
            base, _, dtype = rest.rpartition("_")
            if (dtype not in compression_lib.DTYPES or base not in names
                    or base == "hier_int8"):
                fail()
            cspec = CompressionSpec(dtype=dtype, scope=scope,
                                    error_feedback=ef)
        return cls(**{op: base for op in _OPS}, overlap=overlap,
                   compression=cspec)


def _as_spec(spec: Union[str, CommSpec, None]) -> CommSpec:
    if spec is None:
        return CommSpec()
    if isinstance(spec, str):
        return CommSpec.from_flag(spec)
    return spec


class Communicator:
    """Mesh-bound SPMD messaging object (see module docstring).

    Data-op methods run *inside* shard_map over ``self.axes`` — either a
    shard_map the caller already has, or one built by ``self.run`` /
    ``self.wrap``.  Ranks are linear C-order over ``self.axes`` (pod
    level first), matching the paper's leader-on-rank-0 convention.
    """

    def __init__(self, mesh: Mesh,
                 spec: Union[str, CommSpec, None] = None,
                 axes: Optional[Sequence[str]] = None):
        self.mesh = mesh
        self.spec = _as_spec(spec)
        self.topo = Topology.from_mesh(mesh, axes=axes)
        # the armed FaultPlan (if any) is captured at construction:
        # maybe_wrap is the identity when chaos is disarmed, so the
        # common path carries zero wrapper overhead
        self.fault_plan = faults.active_plan()

        def make(op: str) -> Transport:
            t = get_transport(getattr(self.spec, op), self.topo)
            if self.spec.compression is not None:
                # compression sits inside chaos: fault retries corrupt
                # the float payload, the clean attempt is the compressed
                # exchange
                t = compression_lib.CompressedTransport(
                    t, self.spec.compression)
            return faults.maybe_wrap(t, self.fault_plan)

        self._t: Dict[str, Transport] = {op: make(op) for op in _OPS}
        self._sync_fn = None

    # -------------------------------------------------------------- identity
    @property
    def axes(self):
        return self.topo.axes

    @property
    def size(self) -> int:
        return self.topo.n_ranks

    def rank(self):
        """Linear rank of the calling shard (traced; in-shard_map)."""
        return self.topo.rank()

    # -------------------------------------------------- point-to-point (p2p)
    def sendrecv(self, x: Any, pairs: Sequence[tuple]) -> Any:
        """Scheduled p2p rounds (the primitive under SendMsg/RecvMsg):
        each (src, dst) pair moves src's leaf values to dst; every other
        rank keeps its own.  Pairs are static linear ranks."""
        pairs = [(self._check_rank(int(s), "src"),
                  self._check_rank(int(d), "dst")) for s, d in pairs]
        dsts = jnp.asarray([d for _, d in pairs], jnp.int32)
        me = self.topo.rank()
        is_dst = jnp.any(me == dsts)

        def leaf(v):
            recv = compat.ppermute(v, self.axes, pairs)
            return jnp.where(is_dst, recv, v)
        return jax.tree.map(leaf, x)

    def send(self, x: Any, dst: int, *, src: int = 0) -> Any:
        """pPython SendMsg: deliver rank ``src``'s value of ``x`` to rank
        ``dst`` (SPMD: both endpoints — and everyone else — execute the
        same call; non-participants pass ``x`` through)."""
        return self.sendrecv(x, [(src, dst)])

    def recv(self, x: Any, src: int, *, dst: int) -> Any:
        """pPython RecvMsg: the receiving spelling of ``send`` — rank
        ``dst`` ends up holding rank ``src``'s value."""
        return self.sendrecv(x, [(src, dst)])

    # ------------------------------------------------------------ collectives
    def barrier(self) -> Array:
        """In-shard_map rank barrier: a zero-byte-ish reduction every rank
        must reach.  Returns a 0-d token to thread into downstream ops."""
        return compat.psum(jnp.zeros((), jnp.float32), self.axes)

    def allreduce(self, x: Any) -> Any:
        return jax.tree.map(self._t["allreduce"].allreduce, x)

    def allreduce_ef(self, x: Any, err: Any):
        """Error-feedback allreduce (in-shard_map): ``v = x + err`` is
        projected through the wire's lossy C(.) *locally* (``qdq``)
        before the exchange; returns ``(allreduce(C(v)), v - C(v))`` —
        the residual to add into the next step's operand.  Because C(v)
        is already on the quantization grid, the first wire hop loses
        nothing; EF re-injects what C itself dropped.  With no
        compression spec C is the identity and the residual stays
        zero."""
        v = jax.tree.map(lambda a, e: a + e.astype(a.dtype), x, err)
        cspec = self.spec.compression
        if cspec is None:
            return self.allreduce(v), jax.tree.map(jnp.zeros_like, v)
        c = jax.tree.map(lambda a: compression_lib.qdq(a, cspec), v)
        resid = jax.tree.map(lambda a, b: a - b, v, c)
        return self.allreduce(c), resid

    def _check_rank(self, rank: int, what: str) -> int:
        if not 0 <= rank < self.size:
            raise ValueError(f"{what}={rank} out of range for "
                             f"{self.size} ranks over axes {self.axes}")
        return rank

    def bcast(self, x: Any, root: int = 0) -> Any:
        self._check_rank(root, "root")
        return jax.tree.map(lambda v: self._t["bcast"].bcast(v, root), x)

    def agg(self, x: Any, root: int = 0) -> Any:
        """Concat-gather every rank's leaf onto ``root`` (flat, (n*size,)
        per leaf); zeros elsewhere — pPython's agg()."""
        self._check_rank(root, "root")
        return jax.tree.map(lambda v: self._t["agg"].agg(v, root), x)

    def scatter(self, x: Any, root: int = 0) -> Any:
        """Inverse of ``agg`` (pPython's root-distributes direction, Fig
        6): rank ``root``'s flat leaf is split into ``size`` blocks and
        rank i keeps block i (zero-padded to equal blocks)."""
        self._check_rank(root, "root")
        return jax.tree.map(lambda v: self._t["scatter"].scatter(v, root), x)

    def reduce_scatter(self, x: Any) -> Any:
        return jax.tree.map(self._t["reduce_scatter"].reduce_scatter, x)

    def allgather(self, x: Any) -> Any:
        """agg visible on every rank (pPython's agg() + bcast)."""
        return jax.tree.map(self._t["allgather"].allgather, x)

    def alltoall(self, x: Any) -> Any:
        """MPI Alltoall — the token-routed exchange under expert-parallel
        MoE dispatch: each leaf's leading dim splits into ``size`` equal
        per-destination blocks; rank i's block j arrives as rank j's
        block i.  Algorithm from ``spec.alltoall`` (XLA ``all_to_all``
        for 'native'; scheduled pairwise ppermute rounds otherwise)."""
        return jax.tree.map(self._t["alltoall"].alltoall, x)

    def alltoallv(self, x: Any, counts) -> Any:
        """Ragged Alltoall (MPI Alltoallv): ``counts`` is a static
        (size, size) matrix, ``counts[i][j]`` = rows rank i sends to
        rank j.  Leaf rows are packed destination-ordered on the way in
        and source-ordered (zero-padded tail) on the way out; see
        ``Transport.alltoallv`` for the exact layout.  Uses the
        ``spec.alltoall`` transport."""
        counts = tuple(tuple(int(c) for c in r) for r in counts)
        if len(counts) != self.size or any(len(r) != self.size
                                           for r in counts):
            raise ValueError(f"counts must be {self.size}x{self.size} "
                             f"for axes {self.axes}")
        return jax.tree.map(
            lambda v: self._t["alltoall"].alltoallv(v, counts), x)

    def redistribute(self, x: Any, src_map, dst_map,
                     shape: Sequence[int]) -> Any:
        """Streamed PGAS redistribution (in-shard_map): move this rank's
        padded local block of a distributed array from ``src_map``'s
        layout to ``dst_map``'s in ONE scheduled Alltoallv — the
        capability pMatlab/pPython name as the library's core, with no
        global materialization and no checkpoint round-trip.

        Each leaf is this rank's OLD block (shape ``(1, *old_pad)`` as
        shard_map presents Dmat storage, or ``old_pad`` bare); the
        result is this rank's NEW block in the same convention.  The
        (counts, send, recv) plan is static numpy computed once per
        (maps, shape) — see :func:`repro.core.dmap.redistribution_plan`;
        the wire exchange runs over the ``spec.alltoall`` transport, so
        tree/serial/hier schedules (and chaos fault injection) apply
        unchanged."""
        from repro.core import dmap as dmap_lib
        shape = tuple(int(s) for s in shape)
        counts, send_idx, recv_idx = dmap_lib.redistribution_plan(
            src_map, dst_map, shape, self.size)
        old_size = int(prod(src_map.local_shape(shape)))
        dst_pad = dst_map.local_shape(shape)
        new_size = int(prod(dst_pad))
        me = self.topo.rank()
        sidx = jnp.take(jnp.asarray(send_idx), me, axis=0)
        ridx = jnp.take(jnp.asarray(recv_idx), me, axis=0)

        def leaf(v):
            lead = v.ndim == len(shape) + 1 and v.shape[0] == 1
            flat = v.reshape(-1)
            if flat.shape[0] != old_size:
                raise ValueError(
                    f"leaf holds {flat.shape[0]} elements; src_map's "
                    f"padded local block is {old_size}")
            payload = jnp.take(flat, jnp.clip(sidx, 0, old_size - 1),
                               axis=0)[:, None]
            rows = self._t["alltoall"].alltoallv(
                payload, counts)[:, 0]
            # scatter source-ordered rows to their cells; -1 padding
            # rows land in a sacrificial slot past the block
            buf = jnp.zeros((new_size + 1,), v.dtype)
            buf = buf.at[jnp.where(ridx >= 0, ridx, new_size)].set(
                rows.astype(v.dtype))
            out = buf[:new_size].reshape(dst_pad)
            return out[None] if lead else out
        return jax.tree.map(leaf, x)

    # ------------------------------------------------------- jit-level entry
    def wrap(self, fn: Callable, *, in_specs=None, out_specs=None,
             manual_axes: Optional[Sequence[str]] = None) -> Callable:
        """shard_map ``fn`` over this communicator's mesh — THE way to
        enter comm ops from jit level; callers never build shard_maps.

        Defaults: replicated in/out (``P()``).  ``manual_axes`` limits
        manual mapping to a subset (e.g. batch axes), leaving the rest to
        GSPMD — such partial maps must run under ``jax.jit``.  On jax
        versions whose partial-manual regions cannot lower scheduled
        primitives (see compat), a rank token is threaded in and the
        comm ops transparently run their masked-psum emulation.
        """
        if in_specs is None:
            in_specs = P()
        if out_specs is None:
            out_specs = P()
        partial = (manual_axes is not None
                   and frozenset(manual_axes) != frozenset(
                       self.mesh.axis_names))
        if not (partial and compat.PARTIAL_MANUAL_NEEDS_EMULATION):
            return compat.shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                                    out_specs=out_specs,
                                    manual_axes=manual_axes)

        if not isinstance(in_specs, (tuple, list)):
            raise TypeError(
                "partial-manual wrap on this jax version threads a rank "
                "token and needs in_specs as an explicit tuple (one spec "
                "per argument)")
        topo = self.topo

        def outer(rank_arr, *args):
            token = compat.enter_partial_manual(
                rank_arr[0], topo.axes, topo.axis_sizes)
            try:
                return fn(*args)
            finally:
                compat.exit_partial_manual(token)

        mapped = compat.shard_map(
            outer, mesh=self.mesh,
            in_specs=(P(topo.axes),) + tuple(in_specs),
            out_specs=out_specs, manual_axes=manual_axes)

        def call(*args):
            ranks = jnp.arange(topo.n_ranks, dtype=jnp.int32)
            return mapped(ranks, *args)
        return call

    def run(self, fn: Callable, *args, in_specs=None, out_specs=None,
            manual_axes: Optional[Sequence[str]] = None):
        """Run ``fn`` (a body using this communicator's ops) under
        shard_map on ``args``."""
        if in_specs is None and args:
            in_specs = tuple(P() for _ in args)
        return self.wrap(fn, in_specs=in_specs, out_specs=out_specs,
                         manual_axes=manual_axes)(*args)

    def sync(self) -> None:
        """Host-blocking device barrier (jit-level ``barrier``): returns
        once every rank of the mesh has reached it."""
        if self._sync_fn is None:
            self._sync_fn = jax.jit(
                self.wrap(lambda t: t + self.barrier(),
                          in_specs=(P(),), out_specs=P()))
        jax.block_until_ready(self._sync_fn(jnp.zeros((), jnp.float32)))

    # ------------------------------------------------------------- caching
    _CACHE: Dict[Any, "Communicator"] = {}

    @classmethod
    def for_mesh(cls, mesh: Mesh,
                 spec: Union[str, CommSpec, None] = None,
                 axes: Optional[Sequence[str]] = None) -> "Communicator":
        """Memoized constructor — hot paths (Dmat ops) share one
        Communicator (and its jitted sync) per (mesh, spec, axes)."""
        key = (mesh, _as_spec(spec), None if axes is None else tuple(axes),
               faults.active_plan())
        comm = cls._CACHE.get(key)
        if comm is None:
            comm = cls._CACHE[key] = cls(mesh, spec, axes)
        return comm
