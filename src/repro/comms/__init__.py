"""Layered messaging — the pPython architecture point that "any other
communication library could be substituted for PythonMPI".

Public surface:
  * :class:`Communicator` — mesh-bound object exposing the full
    PythonMPI surface (send/recv/barrier/bcast/agg/allreduce/
    reduce_scatter/allgather/alltoall(v)/redistribute) plus the
    ``run``/``wrap`` jit-level entry.
  * :class:`CommSpec` — per-op algorithm selection.
  * :class:`Topology` — the (pod, in_axes) hierarchy, derived from a
    mesh in exactly one place.
  * transport registry — ``register_transport`` / ``get_transport`` /
    ``available_transports`` (native, tree, serial, hier, and the
    ``hier_int8`` compression alias).
  * wire compression — :class:`CompressionSpec` /
    :class:`CompressedTransport` (``repro.comms.compression``):
    int8/fp8/int4 per-block quantization composable with any transport,
    plus error-feedback accumulation (``Communicator.allreduce_ef``).
  * fault injection — :class:`FaultPlan` / :class:`HostEvent` and the
    ``faults.arm``/``armed`` switches; Communicators built while a plan
    is armed wrap every transport in deterministic chaos (see
    ``repro.comms.faults``).
"""
from repro.comms import faults
from repro.comms.communicator import CommSpec, Communicator
from repro.comms.compression import CompressedTransport, CompressionSpec
from repro.comms.faults import FaultPlan, HostEvent
from repro.comms.topology import Topology
from repro.comms.transports import (Transport, available_transports,
                                    get_transport, register_transport)

__all__ = ["Communicator", "CommSpec", "Topology", "Transport",
           "available_transports", "get_transport", "register_transport",
           "CompressionSpec", "CompressedTransport",
           "FaultPlan", "HostEvent", "faults"]
