"""Layered messaging — the pPython architecture point that "any other
communication library could be substituted for PythonMPI".

Public surface:
  * :class:`Communicator` — mesh-bound object exposing the full
    PythonMPI surface (send/recv/barrier/bcast/agg/allreduce/
    reduce_scatter/allgather/alltoall(v)/redistribute) plus the
    ``run``/``wrap`` jit-level entry.
  * :class:`CommSpec` — per-op algorithm selection.
  * :class:`Topology` — the (pod, in_axes) hierarchy, derived from a
    mesh in exactly one place.
  * transport registry — ``register_transport`` / ``get_transport`` /
    ``available_transports`` (native, tree, serial, hier, hier_int8).
  * fault injection — :class:`FaultPlan` / :class:`HostEvent` and the
    ``faults.arm``/``armed`` switches; Communicators built while a plan
    is armed wrap every transport in deterministic chaos (see
    ``repro.comms.faults``).
"""
from repro.comms import faults
from repro.comms.communicator import CommSpec, Communicator
from repro.comms.faults import FaultPlan, HostEvent
from repro.comms.topology import Topology
from repro.comms.transports import (Transport, available_transports,
                                    get_transport, register_transport)

__all__ = ["Communicator", "CommSpec", "Topology", "Transport",
           "available_transports", "get_transport", "register_transport",
           "FaultPlan", "HostEvent", "faults"]
