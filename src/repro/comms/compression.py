"""Composable compressed communication with error feedback.

The paper's headline finding is that large-message communication is
bandwidth-bound: at scale, bytes-on-wire dominate both PythonMPI and
mpi4py.  ``hier_int8`` proved that cross-pod int8 compression recovers
bandwidth, but it was a one-off baked into one transport.  This module
generalizes it into a layer any registered transport composes with:

* :class:`CompressionSpec` — what to quantize (``dtype`` int8 / fp8-e4m3
  / int4-packed), at what granularity (``block`` elements per float32
  amax scale; ``None`` = per-tensor), on which legs (``scope``
  'cross-pod' = pod-axis hops only, 'all' = every leg), and how to carry
  reductions (``reduce`` 'gather' = exchange quantized payloads and sum
  after dequant — true wire reduction; 'qsum' = pmax-shared scale +
  exact int32 psum — the legacy ``hier_int8`` arithmetic, bit-for-bit).
* :class:`CompressedTransport` — wraps any transport.  It does NOT
  reimplement any schedule: it enters a context under which the compat
  wire primitives (``ppermute`` / ``all_gather_tiled`` / ``psum`` /
  ``psum_scatter_blocks`` / ``all_to_all_blocks``) intercept floating
  payloads on in-scope axes, so tree rounds, hier legs, and native
  collectives all move quantized bytes without knowing it.
* quantize/dequantize — the layout-aware per-block formulation:
  flatten -> pad -> reshape (blocks, B) -> per-block amax scale -> cast
  (-> nibble-pack for int4).  Per-block scales bound the error by the
  block's own dynamic range instead of the tensor's.
* error feedback — ``qdq`` is the local lossy projection C(x); EF keeps
  ``e' = v - C(v)`` where ``v = g + e`` and sends C(v), so quantization
  error is re-injected into the next step instead of lost
  (``Communicator.allreduce_ef`` / the ``*_ef`` grad-comms modes).

``hier_int8`` is re-registered here as ``hier`` + :data:`LEGACY_INT8`
(per-tensor qsum, cross-pod) — same name, same bits, one code path.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import FrozenSet, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.comms import compat
from repro.comms import transports as transports_lib
from repro.comms.transports import Transport

Array = jax.Array

DTYPES = ("int8", "fp8", "int4")
SCOPES = ("cross-pod", "all")
REDUCES = ("gather", "qsum")

#: e4m3 is present on the pinned jax; keep a bf16 fallback wire container
#: (2 bytes) so the layer degrades instead of breaking on older stacks.
_FP8 = getattr(jnp, "float8_e4m3fn", None)
_QMAX = {"int8": 127.0, "int4": 7.0, "fp8": 448.0}


@dataclasses.dataclass(frozen=True)
class CompressionSpec:
    """How to compress wire payloads (see module docstring).

    ``dtype``  — int8 | fp8 (e4m3) | int4 (two values per byte).
    ``block``  — elements per float32 scale (layout-aware per-block
                 amax); ``None`` = one scale per tensor (the legacy
                 formulation).  Must be even for int4.
    ``scope``  — 'cross-pod' (only hops over the topology's pod/DCI
                 axis) or 'all' (every leg).
    ``error_feedback`` — carry the residual ``v - C(v)`` into the next
                 step's gradient (consumed by train/steps.py).
    ``reduce`` — psum-leg strategy: 'gather' exchanges quantized
                 payloads and sums after dequantization (wire bytes
                 actually shrink); 'qsum' shares a pmax scale and psums
                 exact int32 payloads (the legacy hier_int8 arithmetic).
                 'qsum' needs an integer dtype.
    """

    dtype: str = "int8"
    block: Optional[int] = 256
    scope: str = "cross-pod"
    error_feedback: bool = False
    reduce: str = "gather"

    def __post_init__(self):
        aliases = {"fp8-e4m3": "fp8", "fp8_e4m3": "fp8",
                   "cross-pod-only": "cross-pod"}
        object.__setattr__(self, "dtype",
                           aliases.get(self.dtype, self.dtype))
        object.__setattr__(self, "scope",
                           aliases.get(self.scope, self.scope))
        if self.dtype not in DTYPES:
            raise ValueError(f"compression dtype {self.dtype!r} not in "
                             f"{DTYPES}")
        if self.scope not in SCOPES:
            raise ValueError(f"compression scope {self.scope!r} not in "
                             f"{SCOPES}")
        if self.reduce not in REDUCES:
            raise ValueError(f"compression reduce {self.reduce!r} not in "
                             f"{REDUCES}")
        if self.reduce == "qsum" and self.dtype == "fp8":
            raise ValueError("reduce='qsum' needs an integer dtype "
                             "(int8/int4); fp8 payloads cannot be summed "
                             "exactly")
        if self.block is not None:
            if self.block <= 0:
                raise ValueError(f"block={self.block} must be positive")
            if self.dtype == "int4" and self.block % 2:
                raise ValueError("int4 packs two values per byte; block "
                                 "must be even")

    # -------------------------------------------------------------- labels
    def tag(self) -> str:
        s = self.dtype
        s += "[tensor]" if self.block is None else f"[b{self.block}]"
        if self.scope == "all":
            s += "+all"
        if self.reduce == "qsum":
            s += "+qsum"
        if self.error_feedback:
            s += "+ef"
        return s

    # ------------------------------------------------------ wire accounting
    def wire_bytes(self, n_elements: int) -> int:
        """Bytes one compressed ``n_elements``-float32 payload occupies on
        an in-scope leg: packed quantized values (padded to whole blocks)
        plus one float32 scale per block."""
        if n_elements <= 0:
            return 0
        B, nb = _row_block(self, n_elements)
        if self.dtype == "int4":
            payload = nb * (B // 2)
        elif self.dtype == "fp8":
            payload = nb * B * (1 if _FP8 is not None else 2)
        else:
            payload = nb * B
        return payload + nb * 4

    def ratio(self, n_elements: int) -> float:
        """Wire-byte reduction vs float32 (>1 = smaller on the wire)."""
        wb = self.wire_bytes(n_elements)
        return (4.0 * n_elements / wb) if wb else 1.0


#: the spec that reproduces the pre-refactor ``hier_int8`` transport
#: bit-for-bit: per-tensor scale, pmax-shared, exact int32 cross-pod sum
LEGACY_INT8 = CompressionSpec(dtype="int8", block=None, scope="cross-pod",
                              reduce="qsum")


# ---------------------------------------------------------------------------
# quantize / dequantize (layout-aware per-block scales)
# ---------------------------------------------------------------------------


def _row_block(spec: CompressionSpec, m: int) -> Tuple[int, int]:
    """Static (block length B, blocks-per-row nb) for an m-element row."""
    if spec.block is None:
        B = m + (m % 2) if spec.dtype == "int4" else m
        B = max(B, 2 if spec.dtype == "int4" else 1)
    else:
        B = int(spec.block)
    nb = max(-(-m // B), 1)
    return B, nb


def container_dtype(spec: CompressionSpec):
    """The on-device dtype holding quantized values before wire packing."""
    if spec.dtype == "fp8":
        return _FP8 if _FP8 is not None else jnp.bfloat16
    return jnp.uint8 if spec.dtype == "int4" else jnp.int8


def _pack_int4(k: Array) -> Array:
    """(r, B) int8 values in [-7, 7] -> (r, B//2) uint8 nibble pairs."""
    u = (k + 8).astype(jnp.uint8)                   # [1, 15]
    return (u[:, 1::2] << 4) | u[:, 0::2]


def _unpack_int4(p: Array) -> Array:
    """(r, B//2) uint8 nibble pairs -> (r, B) int8 values."""
    lo = (p & 0xF).astype(jnp.int8) - 8
    hi = (p >> 4).astype(jnp.int8) - 8
    return jnp.stack([lo, hi], axis=2).reshape(p.shape[0], 2 * p.shape[1])


def quantize_rows(rows: Array, spec: CompressionSpec):
    """Quantize each row independently (rows are self-contained payloads,
    e.g. per-destination alltoall blocks).

    ``rows`` (r, m) floating -> (container (r, nb * B'), scales (r, nb))
    where B' is the packed per-block width.  The per-block pipeline is
    the layout-aware formulation: reshape to (r*nb, B), amax scale per
    block, cast (and nibble-pack for int4)."""
    r, m = rows.shape
    B, nb = _row_block(spec, m)
    xb = rows.astype(jnp.float32)
    if nb * B != m:
        xb = jnp.pad(xb, ((0, 0), (0, nb * B - m)))
    xb = xb.reshape(r * nb, B)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / _QMAX[spec.dtype]
    if spec.dtype == "fp8":
        q = (xb / scale).astype(container_dtype(spec))
    else:
        qmax = _QMAX[spec.dtype]
        q = jnp.clip(jnp.round(xb / scale), -qmax, qmax).astype(jnp.int8)
        if spec.dtype == "int4":
            q = _pack_int4(q)
    return q.reshape(r, -1), scale.reshape(r, nb)


def dequantize_rows(q: Array, scales: Array, spec: CompressionSpec,
                    m: int, dtype) -> Array:
    """Inverse of :func:`quantize_rows`: -> (r, m) in ``dtype``."""
    r, nb = scales.shape
    qb = q.reshape(r * nb, -1)
    if spec.dtype == "int4":
        xb = _unpack_int4(qb).astype(jnp.float32)
    else:
        xb = qb.astype(jnp.float32)
    xb = xb * scales.reshape(r * nb, 1)
    return xb.reshape(r, -1)[:, :m].astype(dtype)


def qdq(x: Array, spec: CompressionSpec) -> Array:
    """The local lossy projection C(x) = dequantize(quantize(x)) — what
    the wire applies to a payload, and what error feedback corrects."""
    if not jnp.issubdtype(x.dtype, jnp.floating) or x.size == 0:
        return x
    q, s = quantize_rows(x.reshape(1, -1), spec)
    return dequantize_rows(q, s, spec, x.size, x.dtype).reshape(x.shape)


# ---------------------------------------------------------------------------
# wire containers: ship integer bytes so emulated (masked-psum) exchanges
# stay exact for every dtype
# ---------------------------------------------------------------------------


def _to_wire(q: Array) -> Array:
    if jnp.issubdtype(q.dtype, jnp.integer):
        return q
    wide = jnp.uint8 if q.dtype.itemsize == 1 else jnp.uint16
    return lax.bitcast_convert_type(q, wide)


def _from_wire(w: Array, spec: CompressionSpec) -> Array:
    c = container_dtype(spec)
    return w if w.dtype == c else lax.bitcast_convert_type(w, c)


# ---------------------------------------------------------------------------
# shared-scale exact-sum reduction (the legacy hier_int8 arithmetic)
# ---------------------------------------------------------------------------


def _qsum_psum(x: Array, axis, spec: CompressionSpec) -> Array:
    """Quantized psum with a pmax-shared scale and an exact int32 sum.

    With ``spec.block is None`` this is op-for-op the pre-refactor
    ``hier_int8`` cross-pod leg (bitwise-identical results); per-block
    specs generalize the same arithmetic with (nb, 1) shared scales."""
    qmax = _QMAX[spec.dtype]
    if spec.block is None:
        scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / qmax
        scale = lax.pmax(scale, axis)
        q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
        return lax.psum(q, axis).astype(x.dtype) * scale
    flat = x.reshape(-1)
    m = flat.shape[0]
    B, nb = _row_block(spec, m)
    if nb * B != m:
        flat = jnp.pad(flat, (0, nb * B - m))
    xb = flat.reshape(nb, B).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), 1, keepdims=True), 1e-8) / qmax
    scale = lax.pmax(scale, axis)
    q = jnp.clip(jnp.round(xb / scale), -qmax, qmax).astype(jnp.int32)
    out = lax.psum(q, axis).astype(jnp.float32) * scale
    return out.reshape(-1)[:m].reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# the wire interception context
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def compressing(spec: CompressionSpec, axes):
    """Activate compression for the compat wire primitives over ``axes``
    for the duration of a transport op trace.  No-op when ``axes`` is
    empty (e.g. cross-pod scope on a mesh with no pod level)."""
    axes = tuple(axes)
    if not axes:
        yield
        return
    token = compat._COMPRESS.set(_WireCompressor(spec, frozenset(axes)))
    try:
        yield
    finally:
        compat._COMPRESS.reset(token)


@contextlib.contextmanager
def _plain():
    """Suspend interception while a handler issues its own wire calls —
    scales and already-quantized payloads must not be re-quantized."""
    token = compat._COMPRESS.set(None)
    try:
        yield
    finally:
        compat._COMPRESS.reset(token)


class _WireCompressor:
    """The object compat's primitives consult (see compat._COMPRESS).

    Each handler suspends the context, quantizes the payload, moves the
    (integer) wire bytes and per-block scales with the *same* compat
    primitive the algorithm asked for, and dequantizes on receipt — so
    scheduled rounds, emulated partial-manual rewrites, and native XLA
    collectives all carry compressed bytes unchanged."""

    def __init__(self, spec: CompressionSpec, axes: FrozenSet[str]):
        self.spec = spec
        self.axes = axes

    def _hits(self, axis) -> bool:
        names = axis if isinstance(axis, (tuple, list)) else (axis,)
        return any(a in self.axes for a in names)

    def applies(self, axis, x) -> bool:
        return (hasattr(x, "dtype")
                and jnp.issubdtype(x.dtype, jnp.floating)
                and getattr(x, "size", 0) > 0
                and self._hits(axis))

    # ------------------------------------------------------------ handlers
    def ppermute(self, x, axis, perm):
        with _plain():
            q, s = quantize_rows(x.reshape(1, -1), self.spec)
            wr = compat.ppermute(_to_wire(q), axis, perm)
            sr = compat.ppermute(s, axis, perm)
            out = dequantize_rows(_from_wire(wr, self.spec), sr, self.spec,
                                  x.size, x.dtype)
            return out.reshape(x.shape)

    def all_gather(self, x, axis):
        with _plain():
            k = compat.axis_size(axis)
            q, s = quantize_rows(x.reshape(1, -1), self.spec)
            w = _to_wire(q)
            wg = compat.all_gather_tiled(w.reshape(-1), axis)
            sg = compat.all_gather_tiled(s.reshape(-1), axis)
            rows = dequantize_rows(
                _from_wire(wg.reshape((k,) + w.shape[1:]), self.spec),
                sg.reshape(k, s.shape[1]), self.spec, x.size, x.dtype)
            # tiled concat semantics: per-rank payloads stack along dim 0
            return rows.reshape((k * x.shape[0],) + x.shape[1:])

    def psum(self, x, axis):
        names = tuple(axis) if isinstance(axis, (tuple, list)) else (axis,)
        raw = tuple(a for a in names if a not in self.axes)
        comp = tuple(a for a in names if a in self.axes)
        y = x
        if raw:
            with _plain():
                y = compat.psum(y, raw if len(raw) > 1 else raw[0])
        for a in comp:
            y = self._reduce_axis(y, a)
        return y

    def _reduce_axis(self, x, a):
        with _plain():
            if self.spec.reduce == "qsum":
                return _qsum_psum(x, a, self.spec)
            # gather-reduce: every rank ships its quantized payload once
            # and sums after dequantization — bytes on the wire shrink by
            # the container ratio (qsum's int32 containers would not)
            k = compat.axis_size(a)
            q, s = quantize_rows(x.reshape(1, -1), self.spec)
            w = _to_wire(q)
            wg = compat.all_gather_tiled(w.reshape(-1), a)
            sg = compat.all_gather_tiled(s.reshape(-1), a)
            rows = dequantize_rows(
                _from_wire(wg.reshape((k,) + w.shape[1:]), self.spec),
                sg.reshape(k, s.shape[1]), self.spec, x.size, jnp.float32)
            return jnp.sum(rows, axis=0).reshape(x.shape).astype(x.dtype)

    def psum_scatter(self, x, axis):
        # compressed reduce + own-row slice: one definition of the op for
        # every schedule (documented simplification — the wire carries
        # whole payloads, like an allreduce)
        full = self.psum(x, axis)
        with _plain():
            me = compat.axis_index(axis)
            return lax.dynamic_slice(
                full, (me,) + (0,) * (x.ndim - 1), (1,) + x.shape[1:]
            ).reshape(x.shape[1:])

    def all_to_all(self, x, axis, dim=0):
        with _plain():
            n = compat.axis_size(axis)
            xm = jnp.moveaxis(x, dim, 0)
            rows = xm.reshape(n, -1)        # one self-contained row per peer
            m = rows.shape[1]
            q, s = quantize_rows(rows, self.spec)
            wr = compat.all_to_all_blocks(_to_wire(q), axis, 0)
            sr = compat.all_to_all_blocks(s, axis, 0)
            out = dequantize_rows(_from_wire(wr, self.spec), sr, self.spec,
                                  m, x.dtype)
            return jnp.moveaxis(out.reshape(xm.shape), 0, dim)


# ---------------------------------------------------------------------------
# the composing transport wrapper
# ---------------------------------------------------------------------------


#: the op surface the pre-refactor ``HierInt8Transport`` compressed:
#: reductions + alltoall cross-pod legs.  Its bcast/agg/allgather/
#: scatter were the plain tree schedules, and consumers (and the
#: transport-equivalence tests) observe those as EXACT — the alias
#: keeps that contract by limiting interception to these ops.
LEGACY_OPS = frozenset(
    {"allreduce", "reduce_scatter", "alltoall", "alltoallv"})


class CompressedTransport(Transport):
    """Compose a :class:`CompressionSpec` with ANY registered transport.

    No schedule is reimplemented: every op runs the inner transport's
    algorithm inside :func:`compressing`, so whatever wire primitives
    that algorithm issues over in-scope axes move quantized payloads.
    Integer payloads (MoE token routing) and out-of-scope legs pass
    through untouched.  ``ops`` limits which methods compress at all
    (``None`` = every op; the ``hier_int8`` alias passes
    :data:`LEGACY_OPS`).  Chaos wrapping (``faults.maybe_wrap``) nests
    *outside* this wrapper, so fault retries corrupt the float payload
    and the final clean attempt is the compressed exchange."""

    def __init__(self, inner: Transport, cspec: CompressionSpec,
                 ops: Optional[FrozenSet[str]] = None):
        super().__init__(inner.topo)
        self.inner = inner
        self.cspec = cspec
        self.ops = None if ops is None else frozenset(ops)
        self.name = f"{inner.name}+{cspec.tag()}"

    def _scope_axes(self) -> Tuple[str, ...]:
        if self.cspec.scope == "all":
            return tuple(self.topo.axes)
        return (self.topo.pod_axis,) if self.topo.pod_axis else ()

    def _cm(self, op: str):
        if self.ops is not None and op not in self.ops:
            return contextlib.nullcontext()
        return compressing(self.cspec, self._scope_axes())

    def allreduce(self, x):
        with self._cm("allreduce"):
            return self.inner.allreduce(x)

    def bcast(self, x, root: int = 0):
        with self._cm("bcast"):
            return self.inner.bcast(x, root)

    def agg(self, x, root: int = 0):
        with self._cm("agg"):
            return self.inner.agg(x, root)

    def allgather(self, x):
        with self._cm("allgather"):
            return self.inner.allgather(x)

    def scatter(self, x, root: int = 0):
        with self._cm("scatter"):
            return self.inner.scatter(x, root)

    def reduce_scatter(self, x):
        with self._cm("reduce_scatter"):
            return self.inner.reduce_scatter(x)

    def alltoall(self, x):
        with self._cm("alltoall"):
            return self.inner.alltoall(x)

    def alltoallv(self, x, counts):
        with self._cm("alltoallv"):
            return self.inner.alltoallv(x, counts)


# ---------------------------------------------------------------------------
# hier_int8: now an alias, not a transport class
# ---------------------------------------------------------------------------


@transports_lib.register_transport("hier_int8")
def _hier_int8_factory(topo) -> CompressedTransport:
    """``hier`` + :data:`LEGACY_INT8` under the historical name, so
    existing specs, benches, and the committed baseline keep working —
    and produce bitwise-identical results to the pre-refactor class."""
    t = CompressedTransport(transports_lib.get_transport("hier", topo),
                            LEGACY_INT8, ops=LEGACY_OPS)
    t.name = "hier_int8"
    return t
