"""Mesh-derived communication topology.

The paper's two hierarchy levels (in-node / off-node, Figs 4 & 6) map
onto the mesh axes: ``pod`` is the off-node (slow DCI) level, every
other axis the in-node (ICI) level.  ``Topology.from_mesh`` derives the
split ONCE — it replaces the ``pod = "pod" if "pod" in mesh.axis_names
else None`` block that used to be copy-pasted into every consumer.

A Topology can cover a *subset* of the mesh axes (e.g. the gradient
exchange runs over the batch axes only, leaving the model axis to
GSPMD): pass ``axes=`` to restrict it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from jax.sharding import Mesh

from repro.comms import compat

POD_AXIS = "pod"


@dataclasses.dataclass(frozen=True)
class Topology:
    """The (pod_axis, in_axes) hierarchy plus static per-axis sizes."""

    pod_axis: Optional[str]
    in_axes: Tuple[str, ...]
    axis_sizes: Tuple[int, ...]        # aligned with ``self.axes``

    @classmethod
    def from_mesh(cls, mesh: Mesh,
                  axes: Optional[Sequence[str]] = None) -> "Topology":
        """Derive the hierarchy from a mesh (optionally restricted to a
        subset of its axes).  The ``pod`` axis, when present, is always
        hoisted to the front — ranks are numbered pod-major (off-node
        level first) regardless of the order given; the remaining axes
        keep their given order."""
        names = tuple(mesh.axis_names) if axes is None else tuple(axes)
        for a in names:
            if a not in mesh.axis_names:
                raise ValueError(f"axis {a!r} not in mesh {mesh.axis_names}")
        pod = POD_AXIS if POD_AXIS in names else None
        in_axes = tuple(a for a in names if a != POD_AXIS)
        ordered = ((pod,) if pod else ()) + in_axes
        sizes = tuple(mesh.shape[a] for a in ordered)
        return cls(pod_axis=pod, in_axes=in_axes, axis_sizes=sizes)

    # ------------------------------------------------------------ static
    @property
    def axes(self) -> Tuple[str, ...]:
        """All participating axes, pod (off-node level) first — the
        C-order rank layout every schedule in core.topology assumes."""
        return ((self.pod_axis,) if self.pod_axis else ()) + self.in_axes

    @property
    def n_ranks(self) -> int:
        n = 1
        for s in self.axis_sizes:
            n *= s
        return n

    @property
    def pod_size(self) -> int:
        return self.axis_sizes[0] if self.pod_axis else 1

    @property
    def in_size(self) -> int:
        return self.n_ranks // self.pod_size

    # ------------------------------------------------- traced (in-shard_map)
    def rank(self):
        """Linear rank of the calling shard (traced value)."""
        return compat.axis_index(self.axes)

    def size(self) -> int:
        """Rank count as seen inside shard_map (== n_ranks)."""
        return compat.axis_size(self.axes)
