"""Transport registry: the per-op algorithms behind the Communicator.

A ``Transport`` implements the collective surface for one topology using
one algorithm family; all methods are per-leaf and run *inside*
shard_map (the Communicator owns pytree mapping and the jit-level
``run`` entry point).  Implementations:

* ``native``    — XLA's own collectives (psum / all_gather /
  psum_scatter): the platform transport, the analogue of the paper's
  mpi4py-over-OpenMPI-RoCE baseline.
* ``tree``      — the paper's node-aware binary-tree schedules over
  explicit ``ppermute`` rounds (PythonMPI analogue: the transport *we*
  schedule).
* ``serial``    — the paper's *initial* serialized broadcast (the Fig 7
  baseline), kept for comparison.
* ``hier``      — beyond-paper reduce-scatter hierarchy.
* ``hier_int8`` — alias registered by ``repro.comms.compression``:
  ``hier`` wrapped in the legacy per-tensor int8 ``CompressionSpec``
  (bitwise-identical to the historical bespoke transport).

Wire compression is NOT a transport concern: any registered transport
composes with ``compression.CompressedTransport``, which intercepts the
compat wire primitives the schedules here already use.

New transports register with ``@register_transport("name")`` — the
swappable-messaging-library architecture point of the paper, made a
one-decorator extension.  The registry maps names to factories taking a
Topology, so plain functions register too (the ``hier_int8`` alias).
"""
from __future__ import annotations

import abc
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.comms import compat
from repro.comms.topology import Topology
from repro.core import collectives as coll

Array = jax.Array

_REGISTRY: Dict[str, Callable[[Topology], "Transport"]] = {}


def register_transport(name: str):
    """Class decorator: make a Transport constructible by name."""
    def deco(cls):
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def get_transport(name: str, topo: Topology) -> "Transport":
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown comms transport {name!r}; "
                         f"available: {sorted(_REGISTRY)}") from None
    return factory(topo)


def available_transports() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


class Transport(abc.ABC):
    """Per-leaf collective ops over a Topology's (pod, in_axes) levels.

    Semantics (all SPMD; ``n`` = topo.n_ranks, ranks linear C-order over
    ``topo.axes``):
      * allreduce(x)        -> elementwise global sum, every rank.
      * bcast(x, root)      -> root's value, every rank.
      * agg(x, root)        -> flat concat of every rank's ``x`` (shape
                               (n * x.size,)) on ``root``; zeros elsewhere
                               (the SPMD-observable form of pPython's
                               "returns on the leader").
      * allgather(x)        -> the same flat concat, on every rank.
      * reduce_scatter(x)   -> global sum, each rank keeping its own
                               1/n block of the (zero-padded) flat value;
                               shape (ceil(x.size / n),).
      * alltoall(x)         -> x's leading dim split into n equal
                               per-destination blocks; block j of the
                               result is rank j's block addressed to
                               this rank (MPI Alltoall).
      * alltoallv(x, counts)-> ragged Alltoall: static (n, n) count
                               matrix, rows packed destination-ordered
                               in, source-ordered out (see method doc).
    """

    name: str = "?"
    # ``a2a_serial`` switches the scheduled all-to-all exchange to the
    # one-pair-per-round baseline.
    a2a_serial: bool = False

    def __init__(self, topo: Topology):
        self.topo = topo

    @abc.abstractmethod
    def allreduce(self, x: Array) -> Array:
        ...

    @abc.abstractmethod
    def bcast(self, x: Array, root: int = 0) -> Array:
        ...

    @abc.abstractmethod
    def agg(self, x: Array, root: int = 0) -> Array:
        ...

    def allgather(self, x: Array) -> Array:
        # default: aggregate onto rank 0, then broadcast the full buffer
        return self.bcast(self.agg(x, root=0), root=0)

    def scatter(self, x: Array, root: int = 0) -> Array:
        """Inverse of agg (paper Fig 6 root-distributes direction):
        ``root``'s flat buffer is split into n equal blocks and rank i
        keeps block i (zero-padded; shape (ceil(x.size / n),)).  Default
        schedule: move the buffer with this transport's bcast, then each
        rank slices its own block — so 'tree'/'serial' scatters inherit
        the paper's broadcast schedules."""
        return self._own_block(self.bcast(x, root).reshape(-1))

    def reduce_scatter(self, x: Array) -> Array:
        return self._own_block(self.allreduce(x).reshape(-1))

    def alltoall(self, x: Array) -> Array:
        """MPI Alltoall (token-routed exchange, the MoE dispatch
        primitive).  Default schedule: per-axis pairwise ppermute rounds
        (``coll.pairwise_alltoall_axis``), in-axes (ICI) exchanged before
        the pod (DCI) axis — node-aware, the Fig 4/6 discipline applied
        to the routed-exchange pattern.  ``native`` overrides with XLA's
        ``all_to_all``."""
        def leg(blocks, axis, dim):
            return coll.pairwise_alltoall_axis(
                blocks, axis, dim=dim, serial=self.a2a_serial)
        return self._per_axis_alltoall(x, leg)

    def alltoallv(self, x: Array, counts) -> Array:
        """Ragged Alltoall (MPI Alltoallv) with a *static* (n, n) count
        matrix — ``counts[i][j]`` rows travel from rank i to rank j (SPMD
        programs need static shapes, so the full matrix is trace-time
        data; validity is positional).

        Input: rank i's payload is the first ``sum(counts[i])`` rows of
        ``x``, ordered by destination; the static leading dim must cover
        the largest sender.  Output: shape (max_recv_total, ...), this
        rank's valid rows are the first ``sum(counts[:][rank])``, ordered
        by source; the tail is zero-padded.  Runs over this transport's
        ``alltoall`` on per-destination blocks padded to the matrix
        maximum, so every transport's schedule applies unchanged."""
        import numpy as np
        n = self.topo.n_ranks
        cm = np.asarray(counts, dtype=np.int32)
        if cm.shape != (n, n) or (cm < 0).any():
            raise ValueError(f"counts must be a non-negative ({n}, {n}) "
                             f"matrix, got shape {cm.shape}")
        need = int(cm.sum(axis=1).max())
        if x.shape[0] < need:
            raise ValueError(f"alltoallv buffer holds {x.shape[0]} rows; "
                             f"largest sender needs {need}")
        C = max(int(cm.max()), 1)
        R = max(int(cm.sum(axis=0).max()), 1)
        me = self.topo.rank()
        cj = jnp.asarray(cm)
        lane = jnp.arange(C, dtype=jnp.int32)

        # pack: destination-ordered compact rows -> (n, C) padded blocks
        row = cj[me]                                   # my send counts
        off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(row)[:-1]])
        src_idx = off[:, None] + lane[None, :]
        valid = lane[None, :] < row[:, None]
        shp = (1,) * (x.ndim - 1)
        packed = jnp.where(
            valid.reshape(valid.shape + shp),
            jnp.take(x, jnp.clip(src_idx, 0, x.shape[0] - 1), axis=0),
            0)

        recv = self.alltoall(packed.reshape((n * C,) + x.shape[1:]))
        recv = recv.reshape((n, C) + x.shape[1:])

        # unpack: (n, C) padded blocks -> source-ordered compact rows
        col = cj[:, me]                                # my recv counts
        out_off = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(col)[:-1]])
        dst_idx = out_off[:, None] + lane[None, :]
        valid2 = lane[None, :] < col[:, None]
        dst_idx = jnp.where(valid2, dst_idx, R)        # pad rows -> drop
        out = jnp.zeros((R,) + x.shape[1:], x.dtype)
        return out.at[dst_idx.reshape(-1)].set(
            recv.reshape((n * C,) + x.shape[1:]), mode="drop")

    # ------------------------------------------------------------- helpers
    def _per_axis_alltoall(self, x: Array, leg) -> Array:
        """Decompose a composite-rank all-to-all into one exchange per
        topology axis.  ``x``'s leading dim is viewed as one block per
        destination rank (linear C-order); axis i's exchange runs on dim
        i of the (axis_sizes..., blk, ...) view — the per-axis results
        compose to the full rank-space exchange.  In-axes run first (the
        ICI level), the pod axis last (DCI)."""
        n = self.topo.n_ranks
        if x.shape[0] % n:
            raise ValueError(f"alltoall leading dim {x.shape[0]} not "
                             f"divisible by {n} ranks")
        if n == 1:
            return x
        shape = x.shape
        sizes = self.topo.axis_sizes
        blocks = x.reshape(tuple(sizes) + (shape[0] // n,) + shape[1:])
        npod = 1 if self.topo.pod_axis else 0
        order = (tuple(enumerate(self.topo.axes))[npod:]
                 + tuple(enumerate(self.topo.axes))[:npod])
        for dim, axis in order:
            blocks = leg(blocks, axis, dim)
        return blocks.reshape(shape)

    def _own_block(self, flat: Array) -> Array:
        """This rank's 1/n block of a replicated flat buffer, zero-padded
        to n equal blocks of ceil(size / n)."""
        n = self.topo.size()
        blk = -(-flat.shape[0] // n)
        if flat.shape[0] != n * blk:
            flat = jnp.pad(flat, (0, n * blk - flat.shape[0]))
        return lax.dynamic_slice(flat, (self.topo.rank() * blk,), (blk,))
    def _gather_all_axes(self, flat: Array) -> Array:
        """Concat-gather over every level, innermost axis first, so block
        order matches the C-order linear rank layout."""
        full = flat
        for a in reversed(self.topo.in_axes):
            full = compat.all_gather_tiled(full, a)
        if self.topo.pod_axis:
            full = compat.all_gather_tiled(full, self.topo.pod_axis)
        return full


@register_transport("native")
class NativeTransport(Transport):
    """XLA-native (the 'mpi4py/RoCE' baseline)."""

    def allreduce(self, x):
        return compat.psum(x, self.topo.axes)

    def bcast(self, x, root: int = 0):
        # XLA has no bcast primitive: all-gather, then select the root's
        # block (works for any root — GSPMD emits this for replication)
        flat = x.reshape(-1)
        full = self._gather_all_axes(flat)
        return full.reshape((self.topo.size(),) + x.shape)[root]

    def agg(self, x, root: int = 0):
        full = self._gather_all_axes(x.reshape(-1))
        me = self.topo.rank()
        return jnp.where(me == root, full, jnp.zeros_like(full))

    def allgather(self, x):
        return self._gather_all_axes(x.reshape(-1))

    def reduce_scatter(self, x):
        n = self.topo.size()
        flat = x.reshape(-1)
        blk = -(-flat.shape[0] // n)
        if flat.shape[0] != n * blk:
            flat = jnp.pad(flat, (0, n * blk - flat.shape[0]))
        return compat.psum_scatter_blocks(flat.reshape(n, blk),
                                          self.topo.axes)

    def alltoall(self, x):
        return self._per_axis_alltoall(
            x, lambda blocks, axis, dim:
               compat.all_to_all_blocks(blocks, axis, dim))


@register_transport("tree")
class TreeTransport(Transport):
    """Paper-faithful node-aware binary trees (PythonMPI analogue)."""

    def allreduce(self, x):
        return coll.tree_allreduce_local(x, pod_axis=self.topo.pod_axis,
                                         in_axes=self.topo.in_axes)

    def bcast(self, x, root: int = 0):
        return coll.two_level_bcast(x, pod_axis=self.topo.pod_axis,
                                    in_axes=self.topo.in_axes, tree=True,
                                    root=root)

    def agg(self, x, root: int = 0):
        return coll.two_level_agg(x.reshape(-1),
                                  pod_axis=self.topo.pod_axis,
                                  in_axes=self.topo.in_axes, root=root)


@register_transport("serial")
class SerialTransport(TreeTransport):
    """The paper's *initial* serialized broadcast — kept for the Fig 7
    comparison.  The broadcast half of allreduce serializes too, and the
    all-to-all runs one (src, dst) pair per round, so this transport is a
    genuine serialized baseline, not an alias of 'tree'."""

    a2a_serial = True

    def allreduce(self, x):
        return coll.tree_allreduce_local(x, pod_axis=self.topo.pod_axis,
                                         in_axes=self.topo.in_axes,
                                         tree_bcast=False)

    def bcast(self, x, root: int = 0):
        return coll.two_level_bcast(x, pod_axis=self.topo.pod_axis,
                                    in_axes=self.topo.in_axes, tree=False,
                                    root=root)


@register_transport("hier")
class HierTransport(TreeTransport):
    """Beyond-paper: in-pod reduce-scatter -> cross-pod all-reduce ->
    in-pod all-gather.  The cross-pod leg goes through ``compat.psum``,
    so a wrapping CompressedTransport quantizes exactly that hop."""

    def allreduce(self, x):
        return coll.hier_allreduce_local(x, pod_axis=self.topo.pod_axis,
                                         in_axes=self.topo.in_axes)
