"""Transport registry: the per-op algorithms behind the Communicator.

A ``Transport`` implements the collective surface for one topology using
one algorithm family; all methods are per-leaf and run *inside*
shard_map (the Communicator owns pytree mapping and the jit-level
``run`` entry point).  Implementations:

* ``native``    — XLA's own collectives (psum / all_gather /
  psum_scatter): the platform transport, the analogue of the paper's
  mpi4py-over-OpenMPI-RoCE baseline.
* ``tree``      — the paper's node-aware binary-tree schedules over
  explicit ``ppermute`` rounds (PythonMPI analogue: the transport *we*
  schedule).
* ``serial``    — the paper's *initial* serialized broadcast (the Fig 7
  baseline), kept for comparison.
* ``hier``      — beyond-paper reduce-scatter hierarchy.
* ``hier_int8`` — ``hier`` with int8 cross-pod compression.

New transports register with ``@register_transport("name")`` — the
swappable-messaging-library architecture point of the paper, made a
one-decorator extension.
"""
from __future__ import annotations

import abc
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.comms import compat
from repro.comms.topology import Topology
from repro.core import collectives as coll

Array = jax.Array

_REGISTRY: Dict[str, Callable[[Topology], "Transport"]] = {}


def register_transport(name: str):
    """Class decorator: make a Transport constructible by name."""
    def deco(cls):
        _REGISTRY[name] = cls
        cls.name = name
        return cls
    return deco


def get_transport(name: str, topo: Topology) -> "Transport":
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown comms transport {name!r}; "
                         f"available: {sorted(_REGISTRY)}") from None
    return factory(topo)


def available_transports() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


class Transport(abc.ABC):
    """Per-leaf collective ops over a Topology's (pod, in_axes) levels.

    Semantics (all SPMD; ``n`` = topo.n_ranks, ranks linear C-order over
    ``topo.axes``):
      * allreduce(x)        -> elementwise global sum, every rank.
      * bcast(x, root)      -> root's value, every rank.
      * agg(x, root)        -> flat concat of every rank's ``x`` (shape
                               (n * x.size,)) on ``root``; zeros elsewhere
                               (the SPMD-observable form of pPython's
                               "returns on the leader").
      * allgather(x)        -> the same flat concat, on every rank.
      * reduce_scatter(x)   -> global sum, each rank keeping its own
                               1/n block of the (zero-padded) flat value;
                               shape (ceil(x.size / n),).
    """

    name: str = "?"

    def __init__(self, topo: Topology):
        self.topo = topo

    @abc.abstractmethod
    def allreduce(self, x: Array) -> Array:
        ...

    @abc.abstractmethod
    def bcast(self, x: Array, root: int = 0) -> Array:
        ...

    @abc.abstractmethod
    def agg(self, x: Array, root: int = 0) -> Array:
        ...

    def allgather(self, x: Array) -> Array:
        # default: aggregate onto rank 0, then broadcast the full buffer
        return self.bcast(self.agg(x, root=0), root=0)

    def scatter(self, x: Array, root: int = 0) -> Array:
        """Inverse of agg (paper Fig 6 root-distributes direction):
        ``root``'s flat buffer is split into n equal blocks and rank i
        keeps block i (zero-padded; shape (ceil(x.size / n),)).  Default
        schedule: move the buffer with this transport's bcast, then each
        rank slices its own block — so 'tree'/'serial' scatters inherit
        the paper's broadcast schedules."""
        return self._own_block(self.bcast(x, root).reshape(-1))

    def reduce_scatter(self, x: Array) -> Array:
        return self._own_block(self.allreduce(x).reshape(-1))

    # ------------------------------------------------------------- helpers
    def _own_block(self, flat: Array) -> Array:
        """This rank's 1/n block of a replicated flat buffer, zero-padded
        to n equal blocks of ceil(size / n)."""
        n = self.topo.size()
        blk = -(-flat.shape[0] // n)
        if flat.shape[0] != n * blk:
            flat = jnp.pad(flat, (0, n * blk - flat.shape[0]))
        return lax.dynamic_slice(flat, (self.topo.rank() * blk,), (blk,))
    def _gather_all_axes(self, flat: Array) -> Array:
        """Concat-gather over every level, innermost axis first, so block
        order matches the C-order linear rank layout."""
        full = flat
        for a in reversed(self.topo.in_axes):
            full = compat.all_gather_tiled(full, a)
        if self.topo.pod_axis:
            full = compat.all_gather_tiled(full, self.topo.pod_axis)
        return full


@register_transport("native")
class NativeTransport(Transport):
    """XLA-native (the 'mpi4py/RoCE' baseline)."""

    def allreduce(self, x):
        return compat.psum(x, self.topo.axes)

    def bcast(self, x, root: int = 0):
        # XLA has no bcast primitive: all-gather, then select the root's
        # block (works for any root — GSPMD emits this for replication)
        flat = x.reshape(-1)
        full = self._gather_all_axes(flat)
        return full.reshape((self.topo.size(),) + x.shape)[root]

    def agg(self, x, root: int = 0):
        full = self._gather_all_axes(x.reshape(-1))
        me = self.topo.rank()
        return jnp.where(me == root, full, jnp.zeros_like(full))

    def allgather(self, x):
        return self._gather_all_axes(x.reshape(-1))

    def reduce_scatter(self, x):
        n = self.topo.size()
        flat = x.reshape(-1)
        blk = -(-flat.shape[0] // n)
        if flat.shape[0] != n * blk:
            flat = jnp.pad(flat, (0, n * blk - flat.shape[0]))
        return compat.psum_scatter_blocks(flat.reshape(n, blk),
                                          self.topo.axes)


@register_transport("tree")
class TreeTransport(Transport):
    """Paper-faithful node-aware binary trees (PythonMPI analogue)."""

    def allreduce(self, x):
        return coll.tree_allreduce_local(x, pod_axis=self.topo.pod_axis,
                                         in_axes=self.topo.in_axes)

    def bcast(self, x, root: int = 0):
        return coll.two_level_bcast(x, pod_axis=self.topo.pod_axis,
                                    in_axes=self.topo.in_axes, tree=True,
                                    root=root)

    def agg(self, x, root: int = 0):
        return coll.two_level_agg(x.reshape(-1),
                                  pod_axis=self.topo.pod_axis,
                                  in_axes=self.topo.in_axes, root=root)


@register_transport("serial")
class SerialTransport(TreeTransport):
    """The paper's *initial* serialized broadcast — kept for the Fig 7
    comparison.  The broadcast half of allreduce serializes too, so this
    transport is a genuine P-1-round baseline, not an alias of 'tree'."""

    def allreduce(self, x):
        return coll.tree_allreduce_local(x, pod_axis=self.topo.pod_axis,
                                         in_axes=self.topo.in_axes,
                                         tree_bcast=False)

    def bcast(self, x, root: int = 0):
        return coll.two_level_bcast(x, pod_axis=self.topo.pod_axis,
                                    in_axes=self.topo.in_axes, tree=False,
                                    root=root)


@register_transport("hier")
class HierTransport(TreeTransport):
    """Beyond-paper: in-pod reduce-scatter -> cross-pod all-reduce ->
    in-pod all-gather, optionally int8-compressed across pods."""

    compress: Optional[str] = None

    def allreduce(self, x):
        return coll.hier_allreduce_local(x, pod_axis=self.topo.pod_axis,
                                         in_axes=self.topo.in_axes,
                                         compress=self.compress)


@register_transport("hier_int8")
class HierInt8Transport(HierTransport):
    compress = "int8"
