"""Deterministic synthetic token pipeline.

Production concerns covered: per-(step, host)-seeded determinism (restart
at step k regenerates the identical batch — checkpoint/restart safe),
host-sharded generation (each host materializes only its slice and the
global array is assembled from per-host shards), and background prefetch
(double buffering on a worker thread, the straggler-mitigation lever the
trainer's watchdog monitors).

The token distribution is a Zipfian-ish mixture with a repeated-ngram
structure so cross-entropy actually decreases during the example runs —
pure uniform tokens would make the e2e train demo meaningless.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.1
    ngram: int = 8


class SyntheticTokens:
    """Deterministic synthetic LM data, sharded over the batch axes."""

    def __init__(self, cfg: DataConfig, mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.mesh = mesh
        # Zipf-ish unnormalized weights over a capped effective vocab
        v_eff = min(cfg.vocab_size, 50_000)
        w = 1.0 / np.arange(1, v_eff + 1) ** cfg.zipf_alpha
        self._probs = w / w.sum()
        self._v_eff = v_eff

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        base = rng.choice(self._v_eff, size=(b, s + 1), p=self._probs)
        # inject learnable structure: repeat the previous ngram sometimes
        n = cfg.ngram
        for off in range(n, s + 1 - n, 2 * n):
            mask = rng.random(b) < 0.5
            base[mask, off:off + n] = base[mask, off - n:off]
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def device_batch(self, step: int):
        host = self.batch_at(step)
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        baxes = tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)
        sh = NamedSharding(self.mesh, P(baxes, None))
        return {k: jax.device_put(v, sh) for k, v in host.items()}

    def __iter__(self) -> Iterator:
        step = 0
        while True:
            yield self.device_batch(step)
            step += 1


class Prefetcher:
    """Background-thread double buffering over any step-indexed source."""

    def __init__(self, source: SyntheticTokens, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.device_batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
