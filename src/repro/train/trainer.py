"""Trainer: the fault-tolerant training loop.

Production concerns implemented (and exercised by tests/examples):
  * jit'd init with target shardings (params never materialize unsharded);
  * microbatched train_step (see steps.py) with selectable gradient
    exchange: 'auto' (GSPMD flat — the mpi4py analogue) or any comms
    transport routed through a mesh-bound repro.comms.Communicator:
    'tree' (paper-faithful two-level binary trees), 'hier'/'hier_int8'
    (beyond-paper reduce-scatter hierarchy with optional cross-pod
    compression), 'native'/'serial' baselines;
  * checkpoint/restart: async sharded checkpoints every N steps, auto
    -resume from LATEST, crash-safe atomic commit;
  * failure injection: ``failure_at`` raises mid-run (tests restart);
  * straggler watchdog: EMA of step time, flags outliers, forces an
    early checkpoint when sustained (the practical mitigation when you
    cannot evict the slow host);
  * elastic re-mesh: on (simulated) device loss, rebuild a smaller mesh
    and restore the checkpoint under the new shardings (see elastic.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import checkpoint as ckpt_lib
from repro.comms import faults
from repro.configs.base import ArchConfig, ShapeSpec
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens
from repro.models.model import Model
from repro.optim.optimizer import OptimizerConfig, opt_init
from repro.train import elastic, steps as steps_lib


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    grad_comms: str = "auto"      # 'auto' (GSPMD) or a comms transport
                                  # name -> CommSpec.from_flag in steps.py
    log_every: int = 10
    keep_last: int = 3
    straggler_factor: float = 3.0
    failure_at: Optional[int] = None     # simulate a crash at this step


class StragglerWatchdog:
    """Step-time EMA; flags sustained outliers and asks for an early
    checkpoint (so a failing host loses minimal work)."""

    def __init__(self, factor: float = 3.0, patience: int = 3):
        self.factor = factor
        self.patience = patience
        self.ema: Optional[float] = None
        self.strikes = 0
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        """Returns True when an early checkpoint is warranted."""
        if self.ema is None:
            self.ema = dt
            return False
        slow = dt > self.factor * self.ema
        self.ema = 0.9 * self.ema + 0.1 * (self.ema if slow else dt)
        self.strikes = self.strikes + 1 if slow else 0
        if self.strikes >= self.patience:
            self.flagged += 1
            self.strikes = 0
            return True
        return False


class Trainer:
    def __init__(self, cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
                 tcfg: TrainerConfig, ocfg: Optional[OptimizerConfig] = None):
        self.cfg, self.shape, self.mesh, self.tcfg = cfg, shape, mesh, tcfg
        self.ocfg = ocfg or OptimizerConfig(
            name=cfg.optimizer, total_steps=tcfg.total_steps)
        self.model = Model(cfg, mesh)
        self.bundle = steps_lib.sharding_bundle(self.model, self.ocfg, shape)
        step_fn, self.n_microbatches = steps_lib.make_train_step(
            self.model, self.ocfg, shape.global_batch,
            grad_comms=tcfg.grad_comms)
        # error-feedback modes thread per-bucket residual state through
        # the step; it is deliberately NOT checkpointed (restore resets
        # it to zeros — one step of residual, benign)
        self.uses_ef = steps_lib.flag_uses_ef(tcfg.grad_comms)
        if self.uses_ef:
            ef_sh = steps_lib.ef_shardings(self.model)
            self.ef_state = steps_lib.ef_init(self.model)
            self.train_step = jax.jit(
                step_fn,
                in_shardings=(self.bundle["params"], self.bundle["opt"],
                              self.bundle["input_shardings"],
                              NamedSharding(mesh, P()), ef_sh),
                out_shardings=(self.bundle["params"], self.bundle["opt"],
                               None, ef_sh),
                donate_argnums=(0, 1, 4))
        else:
            self.ef_state = None
            self.train_step = jax.jit(
                step_fn,
                in_shardings=(self.bundle["params"], self.bundle["opt"],
                              self.bundle["input_shardings"],
                              NamedSharding(mesh, P())),
                out_shardings=(self.bundle["params"], self.bundle["opt"],
                               None),
                donate_argnums=(0, 1))
        self.checkpointer = ckpt_lib.AsyncCheckpointer(
            tcfg.ckpt_dir, keep_last=tcfg.keep_last)
        self.watchdog = StragglerWatchdog(tcfg.straggler_factor)
        self.data = SyntheticTokens(
            DataConfig(vocab_size=cfg.vocab_size, seq_len=shape.seq_len,
                       global_batch=shape.global_batch), mesh)
        self.history: list = []
        # wall time the first step of this run completed — the "resume"
        # end of the supervisor's detect-to-resume measurement
        self.first_step_done_at: Optional[float] = None

    # ------------------------------------------------------------- state
    def init_state(self, seed: int = 0):
        init = jax.jit(
            lambda k: self.model.init(k),
            out_shardings=self.bundle["params"])
        params = init(jax.random.PRNGKey(seed))
        oinit = jax.jit(lambda p: opt_init(self.ocfg, p),
                        out_shardings=self.bundle["opt"])
        opt_state = oinit(params)
        return params, opt_state

    def try_restore(self):
        step = ckpt_lib.latest_step(self.tcfg.ckpt_dir)
        if step is None:
            return None
        params = ckpt_lib.restore(
            self.tcfg.ckpt_dir, step,
            {"params": self.bundle["abstract_params"],
             "opt": self.bundle["abstract_opt"]},
            {"params": self.bundle["params"], "opt": self.bundle["opt"]})
        return step + 1, params["params"], params["opt"]

    # --------------------------------------------------------------- run
    def run(self, resume: bool = True, state: Optional[tuple] = None,
            start_step: int = 0) -> Dict[str, Any]:
        """Run to ``total_steps``.  ``state=(params, opt)`` (live arrays
        or host snapshots) resumes from in-memory state at ``start_step``
        with NO checkpoint round-trip — the scale-up path; otherwise
        ``resume`` restores LATEST from disk if present."""
        if state is not None:
            start = start_step
            params, opt_state = elastic.live_redistribute(
                state, (self.bundle["params"], self.bundle["opt"]))
            print(f"[trainer] live state redistributed, resuming at "
                  f"step {start}")
        else:
            restored = self.try_restore() if resume else None
            if restored is not None:
                start, params, opt_state = restored
                print(f"[trainer] restored checkpoint, resuming at "
                      f"step {start}")
            else:
                start = 0
                params, opt_state = self.init_state()
        prefetch = Prefetcher(self.data, start_step=start)
        tc = self.tcfg
        metrics = {}
        try:
            for step in range(start, tc.total_steps):
                if tc.failure_at is not None and step == tc.failure_at:
                    raise RuntimeError(f"injected failure at step {step}")
                ev = faults.host_event(step)
                if ev is not None:
                    faults.consume(ev)
                    if ev.kind == faults.LOSE:
                        # the lost ranks' live state is gone: shrink and
                        # restore from the last checkpoint (supervisor)
                        raise elastic.DeviceLossError(step, ev.n_devices)
                    # capacity returned: nothing lost — hand the LIVE
                    # state up for redistribution onto the grown mesh
                    raise elastic.DeviceRestoreInterrupt(
                        step, ev.n_devices, (params, opt_state))
                t0 = time.time()
                got_step, batch = prefetch.next()
                assert got_step == step, (got_step, step)
                if self.uses_ef:
                    params, opt_state, metrics, self.ef_state = (
                        self.train_step(params, opt_state, batch,
                                        jnp.asarray(step, jnp.int32),
                                        self.ef_state))
                else:
                    params, opt_state, metrics = self.train_step(
                        params, opt_state, batch,
                        jnp.asarray(step, jnp.int32))
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                if self.first_step_done_at is None:
                    self.first_step_done_at = time.time()
                want_early_ckpt = self.watchdog.observe(dt)
                self.history.append(
                    {"step": step, "loss": float(metrics["loss"]),
                     "grad_norm": float(metrics["grad_norm"]),
                     "lr": float(metrics["lr"]), "dt": dt})
                if step % tc.log_every == 0:
                    print(f"[trainer] step {step} loss="
                          f"{float(metrics['loss']):.4f} dt={dt*1e3:.0f}ms")
                if want_early_ckpt or (
                        step > 0 and step % tc.checkpoint_every == 0):
                    self.checkpointer.save_async(
                        step, {"params": params, "opt": opt_state})
        finally:
            prefetch.close()
            # flush any in-flight async save: a crash mid-run must still
            # commit the last snapshot, or failover restores a stale step
            self.checkpointer.wait()
        ckpt_lib.save(self.tcfg.ckpt_dir, tc.total_steps - 1,
                      {"params": params, "opt": opt_state},
                      keep_last=tc.keep_last)
        return {"params": params, "opt": opt_state,
                "history": self.history,
                "flagged": self.watchdog.flagged,
                "straggler_flags": self.watchdog.flagged,
                "final_loss": float(metrics["loss"]) if metrics else None}
