"""jit-able step functions: train (with microbatch gradient accumulation),
prefill and decode.  These are what the launcher jits and the dry-run
lowers; the Trainer loop wraps them with checkpointing/fault handling.

The data-parallel gradient exchange comes in three shapes (selected by
``grad_comms``, see :func:`make_train_step`):

* ``auto`` — GSPMD inserts flat all-reduces (the mpi4py analogue);
* explicit *blocking* — each microbatch's gradients are all-reduced
  through a mesh-bound Communicator inside the accumulation scan, in
  per-layer-group buckets;
* explicit *overlap* (``<transport>_overlap``) — a one-slot-deep
  double-buffered pipeline (mirroring the serve engine's one-tick
  overlap): the exchange of microbatch *i*'s buckets is issued at the
  top of iteration *i+1*, before that microbatch's forward/backward —
  no data dependence links them, so XLA is free to run the in-flight
  collective behind the compute.

Compressed modes (``<transport>_<int8|fp8|int4>[_ef]``) put a
``CompressionSpec`` on the CommSpec so every wire leg in scope moves
quantized bytes; ``_ef`` additionally threads per-bucket error-feedback
residuals through the step (``v = g + e`` is projected through the
wire's lossy C(.) locally, ``e' = v - C(v)`` carries to the next
exchange), making compressed training converge like exact.  EF state is
per-rank, bucket-shaped, and NOT checkpointed — restore resets it to
zeros, which costs one step of residual (benign).  Both compose with
``_overlap``.
"""
from __future__ import annotations

import functools
from math import prod
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec, input_specs
from repro.models import partition
from repro.models.model import Model
from repro.optim.optimizer import (OptimizerConfig, clip_by_global_norm,
                                   opt_init, opt_pspecs, opt_update)

#: every accepted --grad-comms flag: GSPMD, the five explicit transports,
#: their double-buffered overlap variants, and the compressed modes
#: (tree/hier x int8/fp8/int4, each with optional _ef and/or _overlap)
_COMPRESSED_MODES = tuple(f"{t}_{d}" for t in ("tree", "hier")
                          for d in ("int8", "fp8", "int4"))
GRAD_COMMS_MODES = tuple(dict.fromkeys(
    ("auto", "native", "tree", "serial", "hier", "hier_int8",
     "native_overlap", "tree_overlap", "serial_overlap",
     "hier_overlap", "hier_int8_overlap")
    + _COMPRESSED_MODES
    + tuple(f"{m}_ef" for m in _COMPRESSED_MODES)
    + tuple(f"{m}_overlap" for m in _COMPRESSED_MODES)
    + tuple(f"{m}_ef_overlap" for m in _COMPRESSED_MODES)))


def flag_uses_ef(grad_comms) -> bool:
    """Whether a --grad-comms flag (or explicit CommSpec) carries
    error-feedback state (and so the step function takes/returns an
    extra ``ef`` argument)."""
    if grad_comms == "auto":
        return False
    from repro.comms import CommSpec
    spec = (grad_comms if isinstance(grad_comms, CommSpec)
            else CommSpec.from_flag(grad_comms))
    return spec.compression is not None and spec.compression.error_feedback


def effective_microbatches(cfg: ArchConfig, global_batch: int,
                           mesh: Mesh) -> int:
    """Largest mb count <= cfg.microbatches such that each microbatch still
    divides over the batch mesh axes."""
    baxes = partition.mesh_batch_axes(mesh, cfg)
    bprod = 1
    for a in baxes:
        bprod *= mesh.shape[a]
    mb = min(cfg.microbatches, max(1, global_batch // max(bprod, 1)))
    while global_batch % mb or (global_batch // mb) % bprod:
        mb -= 1
        if mb <= 1:
            return 1
    return mb


def grad_bucket_indices(tree) -> List[List[int]]:
    """Partition a gradient tree's flat leaves into per-layer-group
    buckets: leaves sharing their first two path entries (e.g.
    ``('blocks', 3)``) form one bucket.  DDP-style bucketing — one
    collective per group instead of one per leaf amortizes the scheduled
    transports' per-round latency, and keeps buckets aligned with
    backprop order so early buckets can be exchanged while later layers
    are still differentiating."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    groups: Dict[Tuple[str, ...], List[int]] = {}
    for i, (path, _) in enumerate(leaves):
        groups.setdefault(tuple(str(p) for p in path[:2]), []).append(i)
    return list(groups.values())


def bucketed_allreduce(comm, tree):
    """All-reduce a float32 gradient tree in per-layer-group buckets
    (each bucket concatenated flat, one collective per bucket).  Buckets
    are issued in reverse definition order — the deepest layers' grads
    exit backprop first, so their exchange can launch while earlier
    layers are still in the backward pass."""
    (paths_and_leaves, treedef) = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [leaf for _, leaf in paths_and_leaves]
    out: List[Any] = [None] * len(leaves)
    for idxs in reversed(grad_bucket_indices(tree)):
        vals = [leaves[i] for i in idxs]
        buf = comm.allreduce(
            jnp.concatenate([v.reshape(-1) for v in vals]))
        off = 0
        for i, v in zip(idxs, vals):
            out[i] = lax.slice(buf, (off,), (off + v.size,)).reshape(v.shape)
            off += v.size
    return jax.tree_util.tree_unflatten(treedef, out)


def bucketed_allreduce_ef(comm, tree, ef):
    """:func:`bucketed_allreduce` with per-bucket error feedback:
    ``v = bucket + e`` is projected through the wire's lossy C(.)
    locally (``compression.qdq``), ``C(v)`` is exchanged (already
    on-grid, so the first hop loses nothing), and ``e' = v - C(v)``
    is returned for the next exchange.  ``ef`` is a tuple of per-rank
    residual rows in :func:`grad_bucket_indices` order (shape
    ``(1, bucket_size)`` inside the wrap); residuals stay at raw
    (pre-normalization) gradient scale."""
    from repro.comms import compression
    cspec = comm.spec.compression
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [leaf for _, leaf in paths_and_leaves]
    out: List[Any] = [None] * len(leaves)
    new_ef = list(ef)
    buckets = grad_bucket_indices(tree)
    for bi in reversed(range(len(buckets))):
        idxs = buckets[bi]
        vals = [leaves[i] for i in idxs]
        v = (jnp.concatenate([t.reshape(-1) for t in vals])
             + ef[bi].reshape(-1))
        c = compression.qdq(v, cspec) if cspec is not None else v
        new_ef[bi] = (v - c).reshape(ef[bi].shape)
        buf = comm.allreduce(c)
        off = 0
        for i, t in zip(idxs, vals):
            out[i] = lax.slice(buf, (off,), (off + t.size,)).reshape(t.shape)
            off += t.size
    return jax.tree_util.tree_unflatten(treedef, out), tuple(new_ef)


# -------------------------------------------------------- error-feedback state

def ef_bucket_sizes(model: Model) -> Tuple[int, ...]:
    """Flat element count of each gradient bucket, in
    :func:`grad_bucket_indices` order."""
    tree = model.init_abstract()
    leaves = jax.tree_util.tree_flatten(tree)[0]
    return tuple(sum(prod(leaves[i].shape) for i in idxs)
                 for idxs in grad_bucket_indices(tree))


def _ef_batch_ranks(model: Model) -> Tuple[Tuple[str, ...], int]:
    baxes = partition.mesh_batch_axes(model.mesh, model.cfg)
    n = 1
    for a in baxes:
        n *= model.mesh.shape[a]
    return tuple(baxes), n


def ef_shardings(model: Model):
    """One NamedSharding per bucket: residuals live as (n_batch_ranks,
    size) arrays sharded over the batch axes, so each rank owns exactly
    its own (1, size) row inside the manual region."""
    baxes, _ = _ef_batch_ranks(model)
    return tuple(NamedSharding(model.mesh, P(baxes))
                 for _ in ef_bucket_sizes(model))


def ef_init(model: Model):
    """Zero-initialized error-feedback state (tuple of per-bucket
    residual arrays, device-placed on their shardings)."""
    _, n = _ef_batch_ranks(model)
    return tuple(
        jax.device_put(jnp.zeros((n, s), jnp.float32), sh)
        for s, sh in zip(ef_bucket_sizes(model), ef_shardings(model)))


def make_train_step(model: Model, ocfg: OptimizerConfig,
                    global_batch: int, grad_comms: str = "auto"):
    """Returns (train_step, mb).  train_step(params, opt_state, batch,
    step) -> (params, opt_state, metrics) — except for error-feedback
    modes (``flag_uses_ef``), where it is train_step(params, opt_state,
    batch, step, ef) -> (params, opt_state, metrics, ef) with ``ef``
    the per-bucket residual state from :func:`ef_init`.

    ``grad_comms`` selects the data-parallel gradient exchange:
      * ``auto``       — GSPMD inserts flat all-reduces (mpi4py analogue);
      * anything else  — an explicit bucketed exchange through a
        mesh-bound :class:`repro.comms.Communicator` over the batch axes,
        with the algorithm chosen by ``CommSpec.from_flag``: ``tree``
        (paper-faithful two-level binary agg+bcast), ``hier``/
        ``hier_int8`` (beyond-paper reduce-scatter hierarchy, optionally
        compressed), ``native``/``serial`` for baselines.  A ``_overlap``
        suffix (``tree_overlap``, ...) keeps the same transport but
        pipelines it: microbatch *i*'s bucket exchange is issued before
        microbatch *i+1*'s forward/backward (one-slot-deep double
        buffering), and the last microbatch's exchange drains after the
        scan.  A ``_<int8|fp8|int4>`` infix (``tree_int8``,
        ``hier_fp8_ef_overlap``, ...) compresses the wire legs in scope
        (see ``repro.comms.compression``); ``_ef`` threads per-bucket
        error-feedback residuals through the step signature.  All
        explicit modes issue ONE loss collective per step (hoisted out
        of the scan), not one per microbatch.
    The explicit modes require non-FSDP params (replicated over the batch
    axes); FSDP archs keep 'auto' (their grads are sharded, and GSPMD's
    reduce-scatter is already the hierarchy).
    """
    cfg = model.cfg
    mesh = model.mesh
    mb = effective_microbatches(cfg, global_batch, model.mesh)
    explicit = grad_comms != "auto"
    if explicit and cfg.use_fsdp:
        raise ValueError("explicit grad_comms needs replicated (non-FSDP) "
                         "params; use grad_comms='auto' for FSDP archs")

    def loss_fn(params, mbatch):
        return model.train_loss(params, mbatch)

    def local_grad(params, mbatch):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mbatch)
        return loss, jax.tree.map(lambda t: t.astype(jnp.float32), g)

    def acc_tree(a, b):
        return jax.tree.map(jnp.add, a, b)

    if explicit:
        from repro.comms import CommSpec, Communicator
        spec = (grad_comms if isinstance(grad_comms, CommSpec)
                else CommSpec.from_flag(grad_comms))
        baxes = partition.mesh_batch_axes(mesh, cfg)
        comm = Communicator(mesh, spec, axes=baxes)
        overlap = spec.overlap and mb > 1
        use_ef = (spec.compression is not None
                  and spec.compression.error_feedback)

        def grad_pipeline(params, mbatches):
            """Loss + globally-summed grads over all microbatches; runs
            inside one shard_map so unreduced (per-rank) gradients can
            live in the scan carry."""
            def take(i):
                return jax.tree.map(lambda x: x[i], mbatches)

            if overlap:
                # prime slot 0: compute its grads, defer their exchange
                loss0, g0 = local_grad(params, take(0))

                def mb_step(carry, mbatch):
                    loss_acc, red_acc, pending = carry
                    # exchange the PREVIOUS microbatch's buckets: no data
                    # dependence on this microbatch's forward/backward,
                    # so the collective runs behind the compute
                    reduced = bucketed_allreduce(comm, pending)
                    loss, g = local_grad(params, mbatch)
                    return (loss_acc + loss,
                            acc_tree(red_acc, reduced), g), ()

                rest = jax.tree.map(lambda x: x[1:], mbatches)
                zeros = jax.tree.map(jnp.zeros_like, g0)
                (loss_sum, red_acc, pending), _ = lax.scan(
                    mb_step, (loss0, zeros, g0), rest)
                # drain: the last microbatch's exchange cannot hide
                grads = acc_tree(red_acc, bucketed_allreduce(comm, pending))
            else:
                def mb_step(acc, mbatch):
                    loss_acc, grad_acc = acc
                    loss, g = local_grad(params, mbatch)
                    return (loss_acc + loss,
                            acc_tree(grad_acc, bucketed_allreduce(comm, g))
                            ), ()

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss_sum, grads), _ = lax.scan(
                    mb_step, (0.0, zeros), mbatches)
            # one loss collective per step, hoisted out of the scan
            loss = comm.allreduce(loss_sum) / (mb * comm.size)
            grads = jax.tree.map(lambda g: g / (mb * comm.size), grads)
            return loss, grads

        def grad_pipeline_ef(params, mbatches, ef):
            """EF variant: the per-bucket residual rides the scan carry,
            every exchange goes through :func:`bucketed_allreduce_ef`,
            and the updated residual is returned alongside the grads
            (still at raw gradient scale — normalization happens after
            the exchange, so next step's residual matches next step's
            raw buckets)."""
            def take(i):
                return jax.tree.map(lambda x: x[i], mbatches)

            if overlap:
                loss0, g0 = local_grad(params, take(0))

                def mb_step(carry, mbatch):
                    loss_acc, red_acc, pending, e = carry
                    reduced, e = bucketed_allreduce_ef(comm, pending, e)
                    loss, g = local_grad(params, mbatch)
                    return (loss_acc + loss,
                            acc_tree(red_acc, reduced), g, e), ()

                rest = jax.tree.map(lambda x: x[1:], mbatches)
                zeros = jax.tree.map(jnp.zeros_like, g0)
                (loss_sum, red_acc, pending, ef), _ = lax.scan(
                    mb_step, (loss0, zeros, g0, ef), rest)
                last, ef = bucketed_allreduce_ef(comm, pending, ef)
                grads = acc_tree(red_acc, last)
            else:
                def mb_step(carry, mbatch):
                    loss_acc, grad_acc, e = carry
                    loss, g = local_grad(params, mbatch)
                    reduced, e = bucketed_allreduce_ef(comm, g, e)
                    return (loss_acc + loss,
                            acc_tree(grad_acc, reduced), e), ()

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss_sum, grads, ef), _ = lax.scan(
                    mb_step, (0.0, zeros, ef), mbatches)
            loss = comm.allreduce(loss_sum) / (mb * comm.size)
            grads = jax.tree.map(lambda g: g / (mb * comm.size), grads)
            return loss, grads, ef

        batch_specs = {k: P(None, baxes, None) for k in ("tokens", "labels")}
        # manual over the batch axes; model/TP axes stay automatic
        if use_ef:
            ef_specs = tuple(P(tuple(baxes))
                             for _ in ef_bucket_sizes(model))
            grad_all = comm.wrap(
                grad_pipeline_ef,
                in_specs=(P(), batch_specs, ef_specs),
                out_specs=(P(), P(), ef_specs), manual_axes=comm.axes)
        else:
            grad_all = comm.wrap(grad_pipeline,
                                 in_specs=(P(), batch_specs),
                                 out_specs=(P(), P()),
                                 manual_axes=comm.axes)
    else:
        use_ef = False
        def grad_all(params, mbatches):
            def mb_step(acc, mbatch):
                loss_acc, grad_acc = acc
                loss, grads = local_grad(params, mbatch)
                return (loss_acc + loss, acc_tree(grad_acc, grads)), ()

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = lax.scan(mb_step, (0.0, zeros), mbatches)
            return loss_sum / mb, jax.tree.map(lambda g: g / mb, grads)

    def reshape(x):
        return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

    if use_ef:
        def train_step(params, opt_state, batch, step, ef):
            loss, grads, ef = grad_all(params,
                                       jax.tree.map(reshape, batch), ef)
            grads, gnorm = clip_by_global_norm(grads, ocfg.clip_norm)
            params, opt_state, lr = opt_update(ocfg, grads, opt_state,
                                               params, step)
            metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
            return params, opt_state, metrics, ef
    else:
        def train_step(params, opt_state, batch, step):
            loss, grads = grad_all(params, jax.tree.map(reshape, batch))
            grads, gnorm = clip_by_global_norm(grads, ocfg.clip_norm)
            params, opt_state, lr = opt_update(ocfg, grads, opt_state,
                                               params, step)
            metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
            return params, opt_state, metrics

    return train_step, mb


def make_prefill_step(model: Model):
    def prefill_step(params, tokens, extras):
        return model.prefill(params, tokens, extras)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, tokens, positions, cache):
        return model.decode_step(params, tokens, positions, cache)
    return decode_step


# ---------------------------------------------------------------------------
# sharding bundles used by launcher + dry-run
# ---------------------------------------------------------------------------

def sharding_bundle(model: Model, ocfg: OptimizerConfig, shape: ShapeSpec):
    """All NamedShardings for one (arch x shape) cell."""
    cfg, mesh = model.cfg, model.mesh
    ns = lambda spec: NamedSharding(mesh, spec)
    tree_ns = lambda tree: jax.tree.map(
        ns, tree, is_leaf=lambda x: isinstance(x, P))

    abstract_params = model.init_abstract()
    pspec = partition.param_pspecs(cfg, abstract_params, mesh)
    out: Dict[str, Any] = {
        "abstract_params": abstract_params,
        "params": tree_ns(pspec),
        "param_pspecs": pspec,
    }
    ispecs = input_specs(cfg, shape)
    out["inputs"] = ispecs
    out["input_shardings"] = tree_ns(
        partition.input_pspecs(cfg, ispecs, mesh))
    if shape.kind == "train":
        abstract_opt = jax.eval_shape(
            functools.partial(opt_init, ocfg), abstract_params)
        out["abstract_opt"] = abstract_opt
        out["opt"] = tree_ns(opt_pspecs(ocfg, pspec, abstract_params))
    if shape.kind in ("prefill", "decode"):
        cspecs = model.cache_specs(shape.global_batch, shape.seq_len)
        out["abstract_cache"] = cspecs
        out["cache"] = tree_ns(partition.cache_pspecs(
            cfg, cspecs, mesh, shape.global_batch))
    return out
