"""jit-able step functions: train (with microbatch gradient accumulation),
prefill and decode.  These are what the launcher jits and the dry-run
lowers; the Trainer loop wraps them with checkpointing/fault handling.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec, input_specs
from repro.models import partition
from repro.models.model import Model
from repro.optim.optimizer import (OptimizerConfig, clip_by_global_norm,
                                   opt_init, opt_pspecs, opt_update)


def effective_microbatches(cfg: ArchConfig, global_batch: int,
                           mesh: Mesh) -> int:
    """Largest mb count <= cfg.microbatches such that each microbatch still
    divides over the batch mesh axes."""
    baxes = partition.mesh_batch_axes(mesh, cfg)
    bprod = 1
    for a in baxes:
        bprod *= mesh.shape[a]
    mb = min(cfg.microbatches, max(1, global_batch // max(bprod, 1)))
    while global_batch % mb or (global_batch // mb) % bprod:
        mb -= 1
        if mb <= 1:
            return 1
    return mb


def make_train_step(model: Model, ocfg: OptimizerConfig,
                    global_batch: int, grad_comms: str = "auto"):
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics).

    ``grad_comms`` selects the data-parallel gradient exchange:
      * ``auto``       — GSPMD inserts flat all-reduces (mpi4py analogue);
      * anything else  — an explicit exchange through a mesh-bound
        :class:`repro.comms.Communicator` over the batch axes, with the
        algorithm chosen by ``CommSpec.from_flag``: ``tree`` (paper-
        faithful two-level binary agg+bcast), ``hier``/``hier_int8``
        (beyond-paper reduce-scatter hierarchy, optionally compressed),
        ``native``/``serial`` for baselines.
    The explicit modes require non-FSDP params (replicated over the batch
    axes); FSDP archs keep 'auto' (their grads are sharded, and GSPMD's
    reduce-scatter is already the hierarchy).
    """
    cfg = model.cfg
    mesh = model.mesh
    mb = effective_microbatches(cfg, global_batch, model.mesh)
    explicit = grad_comms != "auto"
    if explicit and cfg.use_fsdp:
        raise ValueError("explicit grad_comms needs replicated (non-FSDP) "
                         "params; use grad_comms='auto' for FSDP archs")

    def loss_fn(params, mbatch):
        return model.train_loss(params, mbatch)

    if explicit:
        from repro.comms import CommSpec, Communicator
        baxes = partition.mesh_batch_axes(mesh, cfg)
        comm = Communicator(mesh, CommSpec.from_flag(grad_comms),
                            axes=baxes)

        def local_grad(params, mbatch):
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mbatch)
            g = comm.allreduce(
                jax.tree.map(lambda t: t.astype(jnp.float32), g))
            g = jax.tree.map(lambda t: t / comm.size, g)
            loss = comm.allreduce(loss) / comm.size
            return loss, g

        batch_specs = {k: P(baxes, None) for k in ("tokens", "labels")}
        # manual over the batch axes; model/TP axes stay automatic
        grad_of = comm.wrap(local_grad, in_specs=(P(), batch_specs),
                            out_specs=(P(), P()), manual_axes=comm.axes)
    else:
        def grad_of(params, mbatch):
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mbatch)
            return loss, g

    def train_step(params, opt_state, batch, step):
        def reshape(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

        mbatches = jax.tree.map(reshape, batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)

        def mb_step(acc, mbatch):
            loss_acc, grad_acc = acc
            loss, grads = grad_of(params, mbatch)
            grad_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
            return (loss_acc + loss, grad_acc), ()

        (loss_sum, grads), _ = lax.scan(mb_step, (0.0, zeros), mbatches)
        grads = jax.tree.map(lambda g: g / mb, grads)
        grads, gnorm = clip_by_global_norm(grads, ocfg.clip_norm)
        params, opt_state = opt_update(ocfg, grads, opt_state, params, step)
        metrics = {"loss": loss_sum / mb, "grad_norm": gnorm,
                   "lr": jnp.zeros((), jnp.float32)}
        return params, opt_state, metrics

    return train_step, mb


def make_prefill_step(model: Model):
    def prefill_step(params, tokens, extras):
        return model.prefill(params, tokens, extras)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, tokens, positions, cache):
        return model.decode_step(params, tokens, positions, cache)
    return decode_step


# ---------------------------------------------------------------------------
# sharding bundles used by launcher + dry-run
# ---------------------------------------------------------------------------

def sharding_bundle(model: Model, ocfg: OptimizerConfig, shape: ShapeSpec):
    """All NamedShardings for one (arch x shape) cell."""
    cfg, mesh = model.cfg, model.mesh
    ns = lambda spec: NamedSharding(mesh, spec)
    tree_ns = lambda tree: jax.tree.map(
        ns, tree, is_leaf=lambda x: isinstance(x, P))

    abstract_params = model.init_abstract()
    pspec = partition.param_pspecs(cfg, abstract_params, mesh)
    out: Dict[str, Any] = {
        "abstract_params": abstract_params,
        "params": tree_ns(pspec),
        "param_pspecs": pspec,
    }
    ispecs = input_specs(cfg, shape)
    out["inputs"] = ispecs
    out["input_shardings"] = tree_ns(
        partition.input_pspecs(cfg, ispecs, mesh))
    if shape.kind == "train":
        abstract_opt = jax.eval_shape(
            functools.partial(opt_init, ocfg), abstract_params)
        out["abstract_opt"] = abstract_opt
        out["opt"] = tree_ns(opt_pspecs(ocfg, pspec, abstract_params))
    if shape.kind in ("prefill", "decode"):
        cspecs = model.cache_specs(shape.global_batch, shape.seq_len)
        out["abstract_cache"] = cspecs
        out["cache"] = tree_ns(partition.cache_pspecs(
            cfg, cspecs, mesh, shape.global_batch))
    return out
