"""jit-able step functions: train (with microbatch gradient accumulation),
prefill and decode.  These are what the launcher jits and the dry-run
lowers; the Trainer loop wraps them with checkpointing/fault handling.

The data-parallel gradient exchange comes in three shapes (selected by
``grad_comms``, see :func:`make_train_step`):

* ``auto`` — GSPMD inserts flat all-reduces (the mpi4py analogue);
* explicit *blocking* — each microbatch's gradients are all-reduced
  through a mesh-bound Communicator inside the accumulation scan, in
  per-layer-group buckets;
* explicit *overlap* (``<transport>_overlap``) — a one-slot-deep
  double-buffered pipeline (mirroring the serve engine's one-tick
  overlap): the exchange of microbatch *i*'s buckets is issued at the
  top of iteration *i+1*, before that microbatch's forward/backward —
  no data dependence links them, so XLA is free to run the in-flight
  collective behind the compute.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec, input_specs
from repro.models import partition
from repro.models.model import Model
from repro.optim.optimizer import (OptimizerConfig, clip_by_global_norm,
                                   opt_init, opt_pspecs, opt_update)

#: every accepted --grad-comms flag: GSPMD, the five explicit transports,
#: and their double-buffered overlap variants
GRAD_COMMS_MODES = ("auto", "native", "tree", "serial", "hier", "hier_int8",
                    "native_overlap", "tree_overlap", "serial_overlap",
                    "hier_overlap", "hier_int8_overlap")


def effective_microbatches(cfg: ArchConfig, global_batch: int,
                           mesh: Mesh) -> int:
    """Largest mb count <= cfg.microbatches such that each microbatch still
    divides over the batch mesh axes."""
    baxes = partition.mesh_batch_axes(mesh, cfg)
    bprod = 1
    for a in baxes:
        bprod *= mesh.shape[a]
    mb = min(cfg.microbatches, max(1, global_batch // max(bprod, 1)))
    while global_batch % mb or (global_batch // mb) % bprod:
        mb -= 1
        if mb <= 1:
            return 1
    return mb


def grad_bucket_indices(tree) -> List[List[int]]:
    """Partition a gradient tree's flat leaves into per-layer-group
    buckets: leaves sharing their first two path entries (e.g.
    ``('blocks', 3)``) form one bucket.  DDP-style bucketing — one
    collective per group instead of one per leaf amortizes the scheduled
    transports' per-round latency, and keeps buckets aligned with
    backprop order so early buckets can be exchanged while later layers
    are still differentiating."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    groups: Dict[Tuple[str, ...], List[int]] = {}
    for i, (path, _) in enumerate(leaves):
        groups.setdefault(tuple(str(p) for p in path[:2]), []).append(i)
    return list(groups.values())


def bucketed_allreduce(comm, tree):
    """All-reduce a float32 gradient tree in per-layer-group buckets
    (each bucket concatenated flat, one collective per bucket).  Buckets
    are issued in reverse definition order — the deepest layers' grads
    exit backprop first, so their exchange can launch while earlier
    layers are still in the backward pass."""
    (paths_and_leaves, treedef) = jax.tree_util.tree_flatten_with_path(tree)
    leaves = [leaf for _, leaf in paths_and_leaves]
    out: List[Any] = [None] * len(leaves)
    for idxs in reversed(grad_bucket_indices(tree)):
        vals = [leaves[i] for i in idxs]
        buf = comm.allreduce(
            jnp.concatenate([v.reshape(-1) for v in vals]))
        off = 0
        for i, v in zip(idxs, vals):
            out[i] = lax.slice(buf, (off,), (off + v.size,)).reshape(v.shape)
            off += v.size
    return jax.tree_util.tree_unflatten(treedef, out)


def make_train_step(model: Model, ocfg: OptimizerConfig,
                    global_batch: int, grad_comms: str = "auto"):
    """Returns train_step(params, opt_state, batch, step) ->
    (params, opt_state, metrics).

    ``grad_comms`` selects the data-parallel gradient exchange:
      * ``auto``       — GSPMD inserts flat all-reduces (mpi4py analogue);
      * anything else  — an explicit bucketed exchange through a
        mesh-bound :class:`repro.comms.Communicator` over the batch axes,
        with the algorithm chosen by ``CommSpec.from_flag``: ``tree``
        (paper-faithful two-level binary agg+bcast), ``hier``/
        ``hier_int8`` (beyond-paper reduce-scatter hierarchy, optionally
        compressed), ``native``/``serial`` for baselines.  A ``_overlap``
        suffix (``tree_overlap``, ...) keeps the same transport but
        pipelines it: microbatch *i*'s bucket exchange is issued before
        microbatch *i+1*'s forward/backward (one-slot-deep double
        buffering), and the last microbatch's exchange drains after the
        scan.  All explicit modes issue ONE loss collective per step
        (hoisted out of the scan), not one per microbatch.
    The explicit modes require non-FSDP params (replicated over the batch
    axes); FSDP archs keep 'auto' (their grads are sharded, and GSPMD's
    reduce-scatter is already the hierarchy).
    """
    cfg = model.cfg
    mesh = model.mesh
    mb = effective_microbatches(cfg, global_batch, model.mesh)
    explicit = grad_comms != "auto"
    if explicit and cfg.use_fsdp:
        raise ValueError("explicit grad_comms needs replicated (non-FSDP) "
                         "params; use grad_comms='auto' for FSDP archs")

    def loss_fn(params, mbatch):
        return model.train_loss(params, mbatch)

    def local_grad(params, mbatch):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, mbatch)
        return loss, jax.tree.map(lambda t: t.astype(jnp.float32), g)

    def acc_tree(a, b):
        return jax.tree.map(jnp.add, a, b)

    if explicit:
        from repro.comms import CommSpec, Communicator
        spec = CommSpec.from_flag(grad_comms)
        baxes = partition.mesh_batch_axes(mesh, cfg)
        comm = Communicator(mesh, spec, axes=baxes)
        overlap = spec.overlap and mb > 1

        def grad_pipeline(params, mbatches):
            """Loss + globally-summed grads over all microbatches; runs
            inside one shard_map so unreduced (per-rank) gradients can
            live in the scan carry."""
            def take(i):
                return jax.tree.map(lambda x: x[i], mbatches)

            if overlap:
                # prime slot 0: compute its grads, defer their exchange
                loss0, g0 = local_grad(params, take(0))

                def mb_step(carry, mbatch):
                    loss_acc, red_acc, pending = carry
                    # exchange the PREVIOUS microbatch's buckets: no data
                    # dependence on this microbatch's forward/backward,
                    # so the collective runs behind the compute
                    reduced = bucketed_allreduce(comm, pending)
                    loss, g = local_grad(params, mbatch)
                    return (loss_acc + loss,
                            acc_tree(red_acc, reduced), g), ()

                rest = jax.tree.map(lambda x: x[1:], mbatches)
                zeros = jax.tree.map(jnp.zeros_like, g0)
                (loss_sum, red_acc, pending), _ = lax.scan(
                    mb_step, (loss0, zeros, g0), rest)
                # drain: the last microbatch's exchange cannot hide
                grads = acc_tree(red_acc, bucketed_allreduce(comm, pending))
            else:
                def mb_step(acc, mbatch):
                    loss_acc, grad_acc = acc
                    loss, g = local_grad(params, mbatch)
                    return (loss_acc + loss,
                            acc_tree(grad_acc, bucketed_allreduce(comm, g))
                            ), ()

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss_sum, grads), _ = lax.scan(
                    mb_step, (0.0, zeros), mbatches)
            # one loss collective per step, hoisted out of the scan
            loss = comm.allreduce(loss_sum) / (mb * comm.size)
            grads = jax.tree.map(lambda g: g / (mb * comm.size), grads)
            return loss, grads

        batch_specs = {k: P(None, baxes, None) for k in ("tokens", "labels")}
        # manual over the batch axes; model/TP axes stay automatic
        grad_all = comm.wrap(grad_pipeline, in_specs=(P(), batch_specs),
                             out_specs=(P(), P()), manual_axes=comm.axes)
    else:
        def grad_all(params, mbatches):
            def mb_step(acc, mbatch):
                loss_acc, grad_acc = acc
                loss, grads = local_grad(params, mbatch)
                return (loss_acc + loss, acc_tree(grad_acc, grads)), ()

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = lax.scan(mb_step, (0.0, zeros), mbatches)
            return loss_sum / mb, jax.tree.map(lambda g: g / mb, grads)

    def train_step(params, opt_state, batch, step):
        def reshape(x):
            return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])

        loss, grads = grad_all(params, jax.tree.map(reshape, batch))
        grads, gnorm = clip_by_global_norm(grads, ocfg.clip_norm)
        params, opt_state, lr = opt_update(ocfg, grads, opt_state, params,
                                           step)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step, mb


def make_prefill_step(model: Model):
    def prefill_step(params, tokens, extras):
        return model.prefill(params, tokens, extras)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, tokens, positions, cache):
        return model.decode_step(params, tokens, positions, cache)
    return decode_step


# ---------------------------------------------------------------------------
# sharding bundles used by launcher + dry-run
# ---------------------------------------------------------------------------

def sharding_bundle(model: Model, ocfg: OptimizerConfig, shape: ShapeSpec):
    """All NamedShardings for one (arch x shape) cell."""
    cfg, mesh = model.cfg, model.mesh
    ns = lambda spec: NamedSharding(mesh, spec)
    tree_ns = lambda tree: jax.tree.map(
        ns, tree, is_leaf=lambda x: isinstance(x, P))

    abstract_params = model.init_abstract()
    pspec = partition.param_pspecs(cfg, abstract_params, mesh)
    out: Dict[str, Any] = {
        "abstract_params": abstract_params,
        "params": tree_ns(pspec),
        "param_pspecs": pspec,
    }
    ispecs = input_specs(cfg, shape)
    out["inputs"] = ispecs
    out["input_shardings"] = tree_ns(
        partition.input_pspecs(cfg, ispecs, mesh))
    if shape.kind == "train":
        abstract_opt = jax.eval_shape(
            functools.partial(opt_init, ocfg), abstract_params)
        out["abstract_opt"] = abstract_opt
        out["opt"] = tree_ns(opt_pspecs(ocfg, pspec, abstract_params))
    if shape.kind in ("prefill", "decode"):
        cspecs = model.cache_specs(shape.global_batch, shape.seq_len)
        out["abstract_cache"] = cspecs
        out["cache"] = tree_ns(partition.cache_pspecs(
            cfg, cspecs, mesh, shape.global_batch))
    return out
