"""Elastic re-meshing: continue training after losing hosts.

The recovery path for "node failure at 1000-chip scale" is:
  1. the watchdog / runtime detects the loss and the job restarts on the
     surviving device set;
  2. ``shrink_mesh`` factors the survivors into the largest (data, model)
     mesh that preserves the model-parallel width (TP width is a property
     of the checkpoint math, data width is free);
  3. the latest checkpoint is restored with the NEW mesh's shardings —
     redistribution between the old and new layouts is exactly a
     resharded load (and, in PGAS terms, a Dmap redistribute);
  4. the batch axes shrink, so ``effective_microbatches`` grows to keep
     the global batch (and thus the training trajectory) identical.

On this CPU container the "failure" is simulated by rebuilding a smaller
virtual mesh; the mechanism (shrink + resharded restore + microbatch
rescale) is the production path.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import checkpoint as ckpt_lib


def shrink_mesh(n_devices: int, model_width: int,
                devices: Optional[Sequence] = None) -> Mesh:
    """Largest (data, model) mesh over ``n_devices`` surviving devices
    that keeps the model axis width (required: checkpoint TP layout)."""
    devs = list(devices if devices is not None else jax.devices())[:n_devices]
    data = len(devs) // model_width
    assert data >= 1, "not enough survivors for the TP width"
    devs = devs[: data * model_width]
    arr = np.array(devs).reshape(data, model_width)
    return Mesh(arr, ("data", "model"))


def remesh_restore(ckpt_dir: str, abstract_tree, new_shardings):
    """Restore LATEST under the new mesh's shardings."""
    step = ckpt_lib.latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    tree = ckpt_lib.restore(ckpt_dir, step, abstract_tree, new_shardings)
    return step, tree
