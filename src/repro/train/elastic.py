"""Elastic re-meshing: continue training across device loss AND return.

The recovery path for "node failure at 1000-chip scale" is:
  1. the watchdog / fault plan / runtime detects the loss and the job
     restarts on the surviving device set;
  2. ``shrink_mesh`` factors the survivors into the largest
     (data, model) mesh that preserves the model-parallel width (TP
     width is a property of the checkpoint math, data width is free);
  3. the latest checkpoint is restored with the NEW mesh's shardings —
     redistribution between the old and new layouts is exactly a
     resharded load (and, in PGAS terms, a Dmap redistribute);
  4. the batch axes shrink, so ``effective_microbatches`` grows to keep
     the global batch (and thus the training trajectory) identical.

Scale-UP is the cheaper direction because nothing was lost: when
capacity returns, ``grow_mesh`` factors the larger device set and
``live_redistribute`` moves the survivors' CURRENT state onto the new
mesh's shardings directly — no checkpoint round-trip.  (At the PGAS
level the same capability is :meth:`Communicator.redistribute`, the
streamed Alltoallv between two Dmaps; for trainer trees the shardings
are GSPMD NamedShardings, so the resharded transfer is a device_put.)

On this CPU container the "failure" is simulated by rebuilding a
smaller virtual mesh (see ``repro.comms.faults.HostEvent``); the
mechanism (shrink + resharded restore + microbatch rescale, grow +
live redistribute) is the production path.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.checkpoint import checkpoint as ckpt_lib


class DeviceLossError(RuntimeError):
    """Raised by the training loop when the armed fault plan kills
    devices: the lost ranks' live state is gone, so the run must shrink
    and restore from the last checkpoint (``n_devices`` = survivors)."""

    def __init__(self, step: int, n_devices: int):
        super().__init__(f"device loss at step {step}: "
                         f"{n_devices} devices remain")
        self.step = step
        self.n_devices = n_devices


class DeviceRestoreInterrupt(Exception):
    """Raised by the training loop when capacity returns: nothing was
    lost, so ``state`` carries the LIVE (params, opt) for the supervisor
    to redistribute onto the grown mesh — no checkpoint round-trip."""

    def __init__(self, step: int, n_devices: int, state: Tuple[Any, Any]):
        super().__init__(f"capacity restored at step {step}: "
                         f"grow to {n_devices} devices")
        self.step = step
        self.n_devices = n_devices
        self.state = state


def remesh(n_devices: int, model_width: int,
           devices: Optional[Sequence] = None) -> Mesh:
    """Largest (data, model) mesh over ``n_devices`` devices that keeps
    the model axis width (required: checkpoint / live-state TP layout).
    Both elastic directions factor through here."""
    devs = list(devices if devices is not None else jax.devices())[:n_devices]
    data = len(devs) // model_width
    assert data >= 1, "not enough devices for the TP width"
    devs = devs[: data * model_width]
    arr = np.array(devs).reshape(data, model_width)
    return Mesh(arr, ("data", "model"))


def shrink_mesh(n_devices: int, model_width: int,
                devices: Optional[Sequence] = None) -> Mesh:
    """Scale-down factoring over the survivors (see module docstring)."""
    return remesh(n_devices, model_width, devices)


def grow_mesh(n_devices: int, model_width: int,
              devices: Optional[Sequence] = None) -> Mesh:
    """Scale-up factoring when capacity returns — the same invariant
    (model width preserved, data width free) from the other direction."""
    return remesh(n_devices, model_width, devices)


def live_redistribute(tree, shardings):
    """Move live state onto a new mesh's shardings — resharded device
    transfer, no checkpoint round-trip.  ``tree`` may hold device arrays
    (old mesh) or host snapshots; ``shardings`` is a matching tree of
    NamedShardings on the new mesh."""
    return jax.tree.map(jax.device_put, tree, shardings)


def remesh_restore(ckpt_dir: str, abstract_tree, new_shardings):
    """Restore LATEST under the new mesh's shardings."""
    step = ckpt_lib.latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    tree = ckpt_lib.restore(ckpt_dir, step, abstract_tree, new_shardings)
    return step, tree
