"""RecoverySupervisor: the elastic training control loop.

One object owns the whole "survive whatever the cluster does" story:

  * it builds the mesh for the CURRENT device population (``remesh``,
    model width preserved) and runs a :class:`Trainer` on it;
  * :class:`~repro.train.elastic.DeviceLossError` (the armed
    :class:`~repro.comms.faults.FaultPlan` killed devices) → shrink and
    restore from the last checkpoint; the replayed steps recompute the
    identical trajectory because the global batch is preserved
    (``effective_microbatches`` rescales) and the data pipeline is
    keyed by step;
  * :class:`~repro.train.elastic.DeviceRestoreInterrupt` (capacity
    returned) → snapshot the LIVE state off the interrupt, grow the
    mesh, and hand the state to ``Trainer.run(state=...)`` which
    redistributes it onto the new shardings — no checkpoint
    round-trip;
  * per-recovery **detect-to-resume** seconds are recorded (exception
    caught → first step completed on the new mesh), and the straggler
    watchdog's ``flagged`` counts are aggregated across incarnations.

The supervisor is what ``launch/chaos.py`` drives and what the chaos
test asserts against: a faulted run's merged history must match the
fault-free run's loss trajectory step for step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import jax

from repro.configs.base import ArchConfig, ShapeSpec
from repro.optim.optimizer import OptimizerConfig
from repro.train import elastic
from repro.train.trainer import Trainer, TrainerConfig


@dataclasses.dataclass
class RecoveryConfig:
    """Knobs of the supervisor itself (the Trainer keeps its own)."""

    model_width: int = 1          # TP width every remesh must preserve
    max_recoveries: int = 8       # hard stop against event-loop bugs


class RecoverySupervisor:
    """Run training to completion across device loss/restore events."""

    def __init__(self, cfg: ArchConfig, shape: ShapeSpec,
                 tcfg: TrainerConfig, rcfg: Optional[RecoveryConfig] = None,
                 ocfg: Optional[OptimizerConfig] = None,
                 devices: Optional[Sequence] = None):
        self.cfg, self.shape, self.tcfg = cfg, shape, tcfg
        self.rcfg = rcfg or RecoveryConfig()
        self.ocfg = ocfg
        self.devices = list(devices if devices is not None
                            else jax.devices())

    def _trainer(self, n_devices: int) -> Trainer:
        mesh = elastic.remesh(n_devices, self.rcfg.model_width,
                              self.devices)
        return Trainer(self.cfg, self.shape, mesh, self.tcfg, self.ocfg)

    def run(self, n_devices: Optional[int] = None) -> Dict[str, Any]:
        n = n_devices if n_devices is not None else len(self.devices)
        state = None
        start = 0
        resume = True
        history: Dict[int, dict] = {}
        flagged = 0
        events: List[dict] = []
        detect_to_resume: List[float] = []
        pending_detect: Optional[float] = None
        summary: Dict[str, Any] = {}
        for incarnation in range(self.rcfg.max_recoveries + 1):
            trainer = self._trainer(n)
            try:
                summary = trainer.run(resume=resume, state=state,
                                      start_step=start)
                self._absorb(trainer, history, pending_detect,
                             detect_to_resume)
                flagged += trainer.watchdog.flagged
                break
            except elastic.DeviceLossError as e:
                t_detect = time.time()
                self._absorb(trainer, history, pending_detect,
                             detect_to_resume)
                flagged += trainer.watchdog.flagged
                print(f"[recovery] {e} — shrinking to {e.n_devices} "
                      f"devices, restoring last checkpoint")
                events.append({"step": e.step, "kind": "lose",
                               "n_devices": e.n_devices})
                n = e.n_devices
                # live state died with the devices: disk restore + replay
                state, resume, start = None, True, 0
                pending_detect = t_detect
            except elastic.DeviceRestoreInterrupt as e:
                t_detect = time.time()
                self._absorb(trainer, history, pending_detect,
                             detect_to_resume)
                flagged += trainer.watchdog.flagged
                print(f"[recovery] {e} — growing to {e.n_devices} "
                      f"devices, live-redistributing state")
                events.append({"step": e.step, "kind": "restore",
                               "n_devices": e.n_devices})
                n = e.n_devices
                # snapshot the live state to host BEFORE the old mesh's
                # arrays go out of scope; the next Trainer.run
                # redistributes it onto the grown mesh's shardings
                state = jax.device_get(e.state)
                resume, start = False, e.step
                pending_detect = t_detect
        else:
            raise RuntimeError(
                f"gave up after {self.rcfg.max_recoveries} recoveries")
        merged = [history[s] for s in sorted(history)]
        summary = dict(summary)
        summary.update({
            "history": merged,
            "flagged": flagged,
            "straggler_flags": flagged,
            "recoveries": len(events),
            "events": events,
            "detect_to_resume_s": detect_to_resume,
            "n_devices_final": n,
        })
        return summary

    @staticmethod
    def _absorb(trainer: Trainer, history: Dict[int, dict],
                pending_detect: Optional[float],
                detect_to_resume: List[float]) -> None:
        """Merge one incarnation's history (keyed by step — replayed
        steps overwrite their pre-failure entries) and close out a
        pending detect-to-resume measurement."""
        for h in trainer.history:
            history[h["step"]] = h
        if pending_detect is not None \
                and trainer.first_step_done_at is not None:
            detect_to_resume.append(
                trainer.first_step_done_at - pending_detect)
