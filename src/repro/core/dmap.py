"""Dmap — pPython's map construct (paper Fig 1), up to 4-D.

A map assigns blocks of a numerical array to processing elements:
  * ``grid``  — processor grid, one entry per distributed dim;
  * ``dist``  — per-dim distribution: ``('b',)`` block, ``('c',)`` cyclic,
                ``('bc', k)`` block-cyclic with block size k;
  * ``procs`` — linear list of ranks holding the data (subsets allowed);
  * ``order`` — 'C' (row-major, Python default) or 'F' (column-major) —
                the paper's ``order`` keyword;
  * ``overlap`` — per-dim halo width (overlapped distributions).

All index math is static numpy; the storage layout contract with Dmat is:
``storage[rank, *local_pad]`` where ``local_pad`` is the per-dim maximum
local extent (ragged tails padded).  ``global_index_arrays`` /
``storage_index_arrays`` are the two gather maps that localize /
globalize — their composition implements redistribution between *any*
two block-cyclic-overlapped maps, the capability the paper calls out as
"highly complex to program for the user but solved by the library".
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple, Union

import numpy as np

DistSpec = Tuple  # ('b',) | ('c',) | ('bc', int)


def _norm_dist(d: Union[str, Tuple]) -> DistSpec:
    if isinstance(d, str):
        if d == "b":
            return ("b",)
        if d == "c":
            return ("c",)
        raise ValueError(d)
    if d[0] in ("b", "c"):
        return tuple(d)
    if d[0] == "bc":
        return ("bc", int(d[1]))
    raise ValueError(d)


@dataclasses.dataclass(frozen=True)
class Dmap:
    grid: Tuple[int, ...]
    dist: Tuple[DistSpec, ...] = ()
    procs: Tuple[int, ...] = ()
    order: str = "C"
    overlap: Tuple[int, ...] = ()

    def __post_init__(self):
        grid = tuple(int(g) for g in self.grid)
        if not 1 <= len(grid) <= 4:
            raise ValueError("pPython maps support 1..4 dims")
        dist = tuple(_norm_dist(d) for d in self.dist) or (("b",),) * len(grid)
        if len(dist) != len(grid):
            raise ValueError("dist/grid rank mismatch")
        procs = tuple(int(p) for p in self.procs) or tuple(
            range(int(np.prod(grid))))
        if len(procs) != int(np.prod(grid)):
            raise ValueError("len(procs) must equal prod(grid)")
        overlap = tuple(int(o) for o in self.overlap) or (0,) * len(grid)
        if self.order not in ("C", "F"):
            raise ValueError("order must be 'C' or 'F'")
        object.__setattr__(self, "grid", grid)
        object.__setattr__(self, "dist", dist)
        object.__setattr__(self, "procs", procs)
        object.__setattr__(self, "overlap", overlap)

    # ------------------------------------------------------------------ dims
    @property
    def ndim(self) -> int:
        return len(self.grid)

    def coords_of_rank_slot(self, slot: int) -> Tuple[int, ...]:
        """Grid coordinates of the slot-th entry of ``procs``."""
        return tuple(np.unravel_index(slot, self.grid, order=self.order))

    # ------------------------------------------------------- per-dim mapping
    def _dim_map(self, n: int, d: int) -> Tuple[np.ndarray, np.ndarray]:
        """For dim d of extent n: arrays (proc_coord[n], local_index[n])."""
        g = self.grid[d]
        idx = np.arange(n)
        kind = self.dist[d][0]
        if kind == "b":
            bsize = -(-n // g)
            coord = np.minimum(idx // bsize, g - 1)
            local = idx - coord * bsize
        elif kind == "c":
            coord = idx % g
            local = idx // g
        else:  # block-cyclic
            k = self.dist[d][1]
            coord = (idx // k) % g
            local = (idx // (g * k)) * k + idx % k
        return coord.astype(np.int64), local.astype(np.int64)

    def local_extent(self, n: int, d: int) -> int:
        """Max local extent along dim d (before overlap)."""
        coord, local = self._dim_map(n, d)
        return int(local.max()) + 1 if n else 0

    def local_shape(self, shape: Sequence[int]) -> Tuple[int, ...]:
        return tuple(self.local_extent(n, d) + 2 * self.overlap[d]
                     for d, n in enumerate(shape))

    # -------------------------------------------------------- gather tables
    @functools.lru_cache(maxsize=64)
    def owner_tables(self, shape: Tuple[int, ...]):
        """Per-dim (coord, local) arrays; cached."""
        if len(shape) != self.ndim:
            raise ValueError("map rank != array rank")
        return tuple(self._dim_map(n, d) for d, n in enumerate(shape))

    def rank_of_coords(self, coords) -> np.ndarray:
        """Grid coords (each an int array) -> rank id from ``procs``."""
        slot = np.ravel_multi_index(coords, self.grid, order=self.order)
        return np.asarray(self.procs, np.int64)[slot]

    def storage_index_arrays(self, shape: Tuple[int, ...], n_ranks: int):
        """Gather map: storage[rank, l0.., lk] = global[i0.., ik].

        Returns (index arrays per global dim shaped like the storage
        (n_ranks, *local_pad), valid mask).  Overlap halos replicate the
        neighbouring rows."""
        tables = self.owner_tables(tuple(shape))
        local_pad = self.local_shape(shape)
        # invert: for each (rank, local) which global index?
        inv = []
        for d, n in enumerate(shape):
            coord, local = tables[d]
            ext = local_pad[d]
            ov = self.overlap[d]
            tab = np.full((self.grid[d], ext), -1, np.int64)
            tab[coord, local + ov] = np.arange(n)
            if ov:
                # halo: replicate neighbour edges (same global indices)
                for c in range(self.grid[d]):
                    own = np.where(coord == c)[0]
                    if own.size == 0:
                        continue
                    lo, hi = own.min(), own.max()
                    tab[c, :ov] = [max(lo - ov + i, 0) for i in range(ov)] \
                        if lo > 0 else tab[c, ov]
                    for i in range(ov):
                        tab[c, ext - ov + i] = min(hi + 1 + i, shape[d] - 1)
            inv.append(tab)
        # rank -> grid coords (slot ordering); ranks outside map -> invalid
        rank_to_slot = np.full((n_ranks,), -1, np.int64)
        for slot, r in enumerate(self.procs):
            if r < n_ranks:
                rank_to_slot[r] = slot
        idx_arrays = []
        valid = np.ones((n_ranks,) + tuple(local_pad), bool)
        for d in range(self.ndim):
            arr = np.zeros((n_ranks,) + tuple(local_pad), np.int64)
            for r in range(n_ranks):
                slot = rank_to_slot[r]
                if slot < 0:
                    valid[r] = False
                    continue
                c = self.coords_of_rank_slot(int(slot))[d]
                view = inv[d][c]
                shp = [1] * self.ndim
                shp[d] = local_pad[d]
                arr[r] = np.broadcast_to(view.reshape(shp), tuple(local_pad))
            idx_arrays.append(arr)
        for a in idx_arrays:
            valid &= a >= 0
        idx_arrays = [np.maximum(a, 0) for a in idx_arrays]
        return idx_arrays, valid

    def global_index_arrays(self, shape: Tuple[int, ...]):
        """Gather map: global[i..] = storage[rank(i..), local(i..)].
        Returns (rank array, per-dim local arrays), each shaped
        ``shape``.  Overlap offsets are applied (owned region starts at
        ``overlap[d]``)."""
        tables = self.owner_tables(tuple(shape))
        coords = []
        locals_ = []
        for d in range(self.ndim):
            coord, local = tables[d]
            shp = [1] * self.ndim
            shp[d] = shape[d]
            coords.append(np.broadcast_to(coord.reshape(shp), shape))
            locals_.append(np.broadcast_to(
                (local + self.overlap[d]).reshape(shp), shape))
        rank = self.rank_of_coords(tuple(coords))
        return rank, locals_


@functools.lru_cache(maxsize=32)
def redistribution_plan(src_map: Dmap, dst_map: Dmap,
                        shape: Tuple[int, ...], n_ranks: int):
    """The static sendrecv/alltoallv plan that moves a distributed array
    from ``src_map``'s storage layout to ``dst_map``'s — the streamed
    form of pPython's redistribute-between-any-two-maps capability (no
    global materialization, no checkpoint round-trip).

    For every cell of the *destination* storage we resolve the global
    element it holds (halo cells resolve to their neighbour's element,
    invalid cells to nothing) and the unique *source* owner of that
    element under ``src_map``.  Grouping by (owner, destination) yields:

      * ``counts``   — (n, n) int64; ``counts[i][j]`` = elements rank i
        sends to rank j;
      * ``send_idx`` — (n, S) int64; rank i's flat indices into its OLD
        padded local block, destination-major (then block-internal
        order), -1 padded to the global max send total S;
      * ``recv_idx`` — (n, R) int64; rank j's flat indices into its NEW
        padded local block, source-major, -1 padded to the global max
        recv total R.

    Both sides order each (src, dst) block identically (by destination
    cell), so an MPI-Alltoallv over these counts delivers every row to
    exactly the cell that requested it.  All math is static numpy; the
    plan is cached per (maps, shape, n_ranks).
    """
    shape = tuple(int(s) for s in shape)
    # destination side: which global element does each new-storage cell
    # hold, and is it valid?
    idx_new, valid_new = dst_map.storage_index_arrays(shape, n_ranks)
    gflat_new = np.ravel_multi_index(
        tuple(a.reshape(n_ranks, -1) for a in idx_new), shape)  # (n, cells)
    valid_new = valid_new.reshape(n_ranks, -1)
    # source side: unique owner rank + old-local flat offset per element
    rank_old, locals_old = src_map.global_index_arrays(shape)
    old_pad = src_map.local_shape(shape)
    off_old = np.ravel_multi_index(tuple(locals_old), tuple(old_pad))
    owner_flat = rank_old.reshape(-1)          # global-flat -> src rank
    offset_flat = off_old.reshape(-1)          # global-flat -> src offset

    counts = np.zeros((n_ranks, n_ranks), np.int64)
    send_lists = [[[] for _ in range(n_ranks)] for _ in range(n_ranks)]
    recv_lists = [[[] for _ in range(n_ranks)] for _ in range(n_ranks)]
    for r in range(n_ranks):
        cells = np.nonzero(valid_new[r])[0]
        if cells.size == 0:
            continue
        owners = owner_flat[gflat_new[r, cells]]
        offsets = offset_flat[gflat_new[r, cells]]
        # source-major, destination-cell order within each source block —
        # the one canonical order both endpoints derive independently
        order = np.argsort(owners, kind="stable")
        for o in np.unique(owners):
            sel = order[owners[order] == o]
            counts[o, r] = sel.size
            send_lists[int(o)][r] = offsets[sel].tolist()
            recv_lists[int(o)][r] = cells[sel].tolist()

    S = max(int(counts.sum(axis=1).max()), 1)
    R = max(int(counts.sum(axis=0).max()), 1)
    send_idx = np.full((n_ranks, S), -1, np.int64)
    recv_idx = np.full((n_ranks, R), -1, np.int64)
    for i in range(n_ranks):
        row = [v for j in range(n_ranks) for v in send_lists[i][j]]
        send_idx[i, :len(row)] = row
    for j in range(n_ranks):
        col = [v for i in range(n_ranks) for v in recv_lists[i][j]]
        recv_idx[j, :len(col)] = col
    return counts, send_idx, recv_idx


def dmap_serial() -> Optional["Dmap"]:
    """The paper's 'set the map to 1' serial fallback."""
    return None
