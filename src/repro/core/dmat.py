"""Dmat — pPython's distributed array, on jax.

Storage contract: ``storage[rank, *local_pad]`` — one padded local block
per device, block-sharded over every mesh axis on dim 0, so PGAS maps of
any block/cyclic/block-cyclic(+overlap) flavour become a *fixed* device
layout plus static index tables (from Dmap).  This keeps the XLA side
trivial (pure gathers) while preserving pPython's full map algebra.

API mirrors pPython: ``zeros/ones/rand(..., map=...)`` return a plain
jnp array when ``map`` is None (the paper's "turn parallelism off by
setting maps to 1"), else a Dmat.  ``agg()`` aggregates onto the leader
rank via the paper's two-level binary-tree gather; ``bcast`` broadcasts
with the tree algorithm; ``redistribute`` remaps between any two maps.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.dmap import Dmap

Array = jax.Array


def _ndev(mesh: Mesh) -> int:
    return int(mesh.devices.size)


def _storage_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(tuple(mesh.axis_names)))


@dataclasses.dataclass
class Dmat:
    storage: Array                 # (n_ranks, *local_pad)
    dmap: Dmap
    shape: Tuple[int, ...]
    mesh: Mesh

    # ------------------------------------------------------------- factory
    @classmethod
    def from_global(cls, arr: Array, dmap: Dmap, mesh: Mesh) -> "Dmat":
        n = _ndev(mesh)
        idx, valid = dmap.storage_index_arrays(tuple(arr.shape), n)
        storage = jnp.where(jnp.asarray(valid),
                            jnp.asarray(arr)[tuple(jnp.asarray(i)
                                                   for i in idx)],
                            0)
        storage = jax.lax.with_sharding_constraint(
            storage, _storage_sharding(mesh))
        return cls(storage, dmap, tuple(arr.shape), mesh)

    # ------------------------------------------------------------ pPython API
    def to_global(self) -> Array:
        """Materialize the global array (gather from owners)."""
        rank, locals_ = self.dmap.global_index_arrays(self.shape)
        return self.storage[(jnp.asarray(rank),)
                            + tuple(jnp.asarray(l) for l in locals_)]

    def local(self, rank: int) -> Array:
        """One rank's padded local block (owned region + halo)."""
        return self.storage[rank]

    def _comm(self):
        # deferred import: repro.comms' transports use the collective
        # primitives from this package (comms -> core.collectives ->
        # core.__init__ -> dmat would cycle at module level)
        from repro.comms import Communicator
        return Communicator.for_mesh(self.mesh, "tree")

    def _storage_spec(self):
        return P(tuple(self.mesh.axis_names))

    def _comm_gather(self, op: str) -> Array:
        """Run a concat-gather comm op over the storage, then reorder the
        full buffer to global indexing (cheap gather; only ranks the op
        delivered to hold data)."""
        comm = self._comm()

        def body(block):
            return getattr(comm, op)(block).reshape(
                (-1,) + block.shape[1:])

        gathered = comm.run(body, self.storage,
                            in_specs=(self._storage_spec(),),
                            out_specs=self._storage_spec())
        rank, locals_ = self.dmap.global_index_arrays(self.shape)
        return gathered[(jnp.asarray(rank),)
                        + tuple(jnp.asarray(l) for l in locals_)]

    def agg(self) -> Array:
        """Aggregate onto the leader (paper's agg(), Fig 4): two-level
        binary-tree gather — result is the global array on rank 0, zeros
        elsewhere (SPMD-observable form of 'returns on the leader')."""
        return self._comm_gather("agg")

    def agg_all(self) -> Array:
        """agg + tree broadcast of the result — every rank gets the full
        storage through the comm layer (the paper's agg() then bcast),
        unlike ``to_global`` which leaves the gather to GSPMD."""
        return self._comm_gather("allgather")

    def redistribute(self, new_map: Dmap, *, method: str = "stream",
                     comm=None) -> "Dmat":
        """Remap between any two block-cyclic-overlapped maps.

        ``method="stream"`` (default) moves only the bytes that change
        owner, in one scheduled Alltoallv over the comm layer
        (:meth:`Communicator.redistribute`) — no global materialization.
        ``method="gather"`` is the original composed-static-gather path
        where XLA/GSPMD emits the communication; kept as the reference
        implementation and for meshes the caller wants GSPMD to handle.
        ``comm`` overrides the memoized tree Communicator (e.g. to pick
        a transport for the wire exchange)."""
        if method == "stream":
            comm = comm if comm is not None else self._comm()

            def body(block):
                return comm.redistribute(block, self.dmap, new_map,
                                         self.shape)

            storage = comm.run(body, self.storage,
                               in_specs=(self._storage_spec(),),
                               out_specs=self._storage_spec())
            return Dmat(storage, new_map, self.shape, self.mesh)
        if method != "gather":
            raise ValueError(f"method must be 'stream' or 'gather', "
                             f"got {method!r}")
        n = _ndev(self.mesh)
        # storage_new[r, l..] = global[g(r, l..)] = storage_old[owner(g)]
        idx_new, valid = new_map.storage_index_arrays(self.shape, n)
        rank_old, locals_old = self.dmap.global_index_arrays(self.shape)
        rsel = jnp.asarray(rank_old)[tuple(jnp.asarray(i) for i in idx_new)]
        lsel = tuple(jnp.asarray(l)[tuple(jnp.asarray(i) for i in idx_new)]
                     for l in locals_old)
        storage = jnp.where(jnp.asarray(valid),
                            self.storage[(rsel,) + lsel], 0)
        storage = jax.lax.with_sharding_constraint(
            storage, _storage_sharding(self.mesh))
        return Dmat(storage, new_map, self.shape, self.mesh)

    def sync_overlap(self) -> "Dmat":
        """Refresh halo regions from owners (overlapped maps)."""
        return Dmat.from_global(self.to_global(), self.dmap, self.mesh)

    # ------------------------------------------------------------- numerics
    def _binop(self, other, op) -> "Dmat":
        if isinstance(other, Dmat):
            assert other.dmap == self.dmap and other.shape == self.shape, \
                "fragmented-PGAS style: match maps before elementwise ops"
            return Dmat(op(self.storage, other.storage), self.dmap,
                        self.shape, self.mesh)
        return Dmat(op(self.storage, other), self.dmap, self.shape,
                    self.mesh)

    def __add__(self, o):
        return self._binop(o, jnp.add)

    def __mul__(self, o):
        return self._binop(o, jnp.multiply)

    def __sub__(self, o):
        return self._binop(o, jnp.subtract)

    def sum(self) -> Array:
        """Global sum: gather each global element from its owner exactly
        once, so halo and padding duplicates never double-count."""
        rank, locals_ = self.dmap.global_index_arrays(self.shape)
        vals = self.storage[(jnp.asarray(rank),)
                            + tuple(jnp.asarray(l) for l in locals_)]
        return vals.sum()


# ---------------------------------------------------------------- factories
def _make(shape, dmap: Optional[Dmap], mesh: Optional[Mesh], fill) -> Array:
    if dmap is None:
        return fill(shape)                      # maps "turned off"
    assert mesh is not None
    return Dmat.from_global(fill(shape), dmap, mesh)


def zeros(shape, map: Optional[Dmap] = None, mesh: Optional[Mesh] = None,
          dtype=jnp.float32):
    return _make(shape, map, mesh, lambda s: jnp.zeros(s, dtype))


def ones(shape, map: Optional[Dmap] = None, mesh: Optional[Mesh] = None,
         dtype=jnp.float32):
    return _make(shape, map, mesh, lambda s: jnp.ones(s, dtype))


def rand(shape, key=None, map: Optional[Dmap] = None,
         mesh: Optional[Mesh] = None, dtype=jnp.float32):
    key = key if key is not None else jax.random.PRNGKey(0)
    return _make(shape, map, mesh,
                 lambda s: jax.random.uniform(key, s, dtype))
