"""The paper's collectives, adapted to the TPU mesh.

Three families, all expressed as `shard_map` bodies over mesh axes:

* ``tree_*``   — pPython's node-aware binary-tree algorithms (paper
  Figs 4/6): log2(P) `ppermute` rounds per hierarchy level, with the
  cross-pod ("off-node") level separated from the in-pod ("in-node")
  level exactly as the paper separates scp-hops from shm-hops.
* ``serial_*`` — pPython's *initial* serialized algorithms (the Fig 7
  baseline): P-1 rounds.
* ``hier_*``   — the beyond-paper production variant: in-pod
  reduce-scatter -> cross-pod all-reduce -> in-pod all-gather.  Wire
  compression (the slow-DCI analogue of the paper's "use the right
  filesystem per level" finding) is layered on by
  ``repro.comms.compression`` intercepting the compat shims these
  schedules already route through.

The native XLA collectives (plain psum/all_gather) play the role of the
paper's mpi4py/OpenMPI-RoCE baseline.

All functions run *inside* shard_map (the jit-level entry point is
``repro.comms.Communicator.run``) and are numerically equivalent to
their flat counterparts — property-tested in
tests/test_collectives_multidev.py on virtual devices.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.comms.compat import (all_gather_tiled as _all_gather,
                                axis_index as _axis_index,
                                axis_size as _axis_size,
                                ppermute as _ppermute,
                                psum as _psum,
                                psum_scatter_blocks as _psum_scatter)
from repro.core import topology

Array = jax.Array


def tree_bcast_axis(x: Array, axis: str, root: int = 0) -> Array:
    """Binary-tree broadcast along one mesh axis (in-shard_map).

    The value on rank ``root`` wins; other ranks' payloads are ignored.
    log2(n) ppermute rounds — the paper's optimized broadcast."""
    n = _axis_size(axis)
    me = _axis_index(axis)
    have = (me == root)
    for rnd in topology.tree_bcast_rounds(n, root):
        recv = _ppermute(x, axis, rnd)
        dsts = jnp.array([d for _, d in rnd], jnp.int32)
        is_dst = jnp.any(me == dsts)
        take = is_dst & ~have
        x = jnp.where(take, recv, x)
        have = have | is_dst
    return x


def serial_bcast_axis(x: Array, axis: str, root: int = 0) -> Array:
    """The paper's initial serialized broadcast: n-1 rounds, root sends to
    one rank per round."""
    n = _axis_size(axis)
    me = _axis_index(axis)
    for rnd in topology.serial_bcast_rounds(n, root):
        recv = _ppermute(x, axis, rnd)
        (src, dst), = rnd
        x = jnp.where(me == dst, recv, x)
    return x


def tree_reduce_axis(x: Array, axis: str, root: int = 0) -> Array:
    """Binary-tree sum-reduction to ``root`` along one axis (the reduce
    flavour of the paper's agg)."""
    n = _axis_size(axis)
    for rnd in topology.tree_gather_rounds(n, root):
        recv = _ppermute(x, axis, rnd)
        me = _axis_index(axis)
        dsts = jnp.array([d for _, d in rnd], jnp.int32)
        is_dst = jnp.any(me == dsts)
        x = jnp.where(is_dst, x + recv, x)
    return x


def tree_gather_axis(x: Array, axis: str, root: int = 0) -> Array:
    """Binary-tree concat-gather to ``root`` (paper Fig 4 agg): message
    doubles each round, exactly the paper's growing aggregation buffers.
    Returns (n*shard,) on root; junk elsewhere (masked by caller)."""
    n = _axis_size(axis)
    me = _axis_index(axis)
    flat = x.reshape(-1)
    local = flat.shape[0]
    buf = flat
    step = 1
    while step < n:
        # senders: ranks at odd multiples of `step` (relative to root)
        pairs = []
        for i in range(0, n, 2 * step):
            j = i + step
            if j < n:
                pairs.append((((j + root) % n), ((i + root) % n)))
        recv = _ppermute(buf, axis, pairs)
        # receivers append; non-receivers keep garbage (masked at the end)
        buf = jnp.concatenate([buf, recv], axis=0)
        step *= 2
    if buf.shape[0] < n * local:  # non-power-of-two: pad
        buf = jnp.pad(buf, (0, n * local - buf.shape[0]))
    # blocks accumulate in root-relative (logical) order; roll back so the
    # concat is in physical rank order for any root
    full = jnp.roll(buf[: n * local].reshape(n, local), root, 0).reshape(-1)
    return jnp.where(me == root, full, jnp.zeros((n * local,), x.dtype))


def pairwise_alltoall_axis(x: Array, axis: str, *, dim: int = 0,
                           serial: bool = False) -> Array:
    """In-shard_map all-to-all along one mesh axis via explicit
    ``ppermute`` rounds (the scheduled-transport analogue of
    ``lax.all_to_all``).

    ``x`` carries one block per destination rank along ``dim`` (size n);
    the result has the same shape with block s along ``dim`` holding rank
    s's block addressed to this rank.  The schedule comes from
    ``topology.pairwise_alltoall_rounds``: disjoint XOR partner pairs for
    power-of-two n (nearest neighbours first), rotation rounds otherwise,
    or one-pair-per-round when ``serial=True`` (the paper's serialized
    baseline).  Round payloads move through ``_ppermute`` (the compat
    shim), so a wire-compression context quantizes them without this
    schedule knowing.
    """
    n = _axis_size(axis)
    if n == 1:
        return x
    me = _axis_index(axis)

    def exchange(blk, perm):
        return _ppermute(blk, axis, perm)

    out = x
    for kind, arg, perm in topology.pairwise_alltoall_rounds(n, serial):
        if kind == "pair":                  # static (src, dst), one pair
            s, d = arg
            recv = exchange(lax.slice_in_dim(x, d, d + 1, axis=dim), perm)
            keep = lax.slice_in_dim(out, s, s + 1, axis=dim)
            upd = jnp.where(me == d, recv, keep)
            out = lax.dynamic_update_slice_in_dim(out, upd, s, axis=dim)
            continue
        if kind == "xor":                   # partner = me ^ k
            send_to = jnp.bitwise_xor(me, arg)
            recv_from = send_to
        else:                               # rotation by k
            send_to = (me + arg) % n
            recv_from = (me - arg) % n
        blk = lax.dynamic_slice_in_dim(x, send_to, 1, axis=dim)
        recv = exchange(blk, perm)
        out = lax.dynamic_update_slice_in_dim(out, recv, recv_from,
                                              axis=dim)
    return out


def ring_allgather_axis(x: Array, axis: str) -> Array:
    """Ring all-gather via n-1 ppermutes (bandwidth-optimal reference for
    the benchmark harness)."""
    n = _axis_size(axis)
    me = _axis_index(axis)
    flat = x.reshape(-1)
    local = flat.shape[0]
    out = jnp.zeros((n, local), x.dtype)
    out = lax.dynamic_update_slice(out, flat[None], (me, 0))
    block = flat
    perm = [(i, (i + 1) % n) for i in range(n)]
    for k in range(1, n):
        block = _ppermute(block, axis, perm)
        src = (me - k) % n
        out = lax.dynamic_update_slice(out, block[None], (src, 0))
    return out.reshape((n,) + x.shape)


# ---------------------------------------------------------------------------
# two-level ("node-aware" -> "pod-aware") compositions
# ---------------------------------------------------------------------------

def _axis_roots(root: int, axes: Sequence[str]) -> dict:
    """Decompose a *global* (linear, C-order over ``axes``) root rank
    into its per-axis coordinates — the root each per-axis schedule
    needs.  Sizes are static inside shard_map."""
    sizes = [_axis_size(a) for a in axes]
    coords = {}
    for a, n in zip(reversed(tuple(axes)), reversed(sizes)):
        coords[a] = root % n
        root //= n
    return coords


def two_level_bcast(x: Array, *, pod_axis: Optional[str], in_axes:
                    Sequence[str], tree: bool = True, root: int = 0) -> Array:
    """Paper Fig 6: broadcast among pod leaders first (off-node level),
    then within each pod (in-node level).  ``root`` is the global linear
    rank (C-order, pod-major); it is decomposed into per-axis roots so
    each level propagates from the fiber that actually holds the data."""
    fn = tree_bcast_axis if tree else serial_bcast_axis
    axes = ((pod_axis,) if pod_axis else ()) + tuple(in_axes)
    roots = _axis_roots(root, axes)
    if pod_axis is not None:
        x = fn(x, pod_axis, roots[pod_axis])
    for a in in_axes:
        x = fn(x, a, roots[a])
    return x


def two_level_agg(x: Array, *, pod_axis: Optional[str],
                  in_axes: Sequence[str], root: int = 0) -> Array:
    """Paper Fig 4: binary-tree aggregation, in-node level first, then
    across nodes.  Concat semantics; the result lands on global rank
    ``root`` in physical C-order (rank = (((pod) * data) + d) * model
    + m), axes gathered innermost-first to match that layout."""
    axes = ((pod_axis,) if pod_axis else ()) + tuple(in_axes)
    roots = _axis_roots(root, axes)
    for a in reversed(tuple(in_axes)):
        x = tree_gather_axis(x, a, roots[a])
    if pod_axis is not None:
        x = tree_gather_axis(x, pod_axis, roots[pod_axis])
    return x


def hier_allreduce_local(x: Array, *, pod_axis: Optional[str],
                         in_axes: Sequence[str]) -> Array:
    """In-shard_map hierarchical all-reduce (beyond-paper production
    variant): reduce-scatter in-pod -> all-reduce cross-pod -> all-gather
    in-pod.  The cross-pod leg goes through the compat ``psum`` shim, so
    a wire-compression context (``hier_int8`` & friends) quantizes
    exactly that hop.  Falls back to plain psum for shapes that do not
    divide."""
    shape = x.shape
    flat = x.reshape(-1)
    n_in = 1
    for a in in_axes:
        n_in *= _axis_size(a)
    if flat.shape[0] % n_in or n_in == 1:
        y = _psum(x, tuple(in_axes))
        if pod_axis is not None:
            y = _psum(y, pod_axis)
        return y
    # in-pod reduce-scatter over the (flattened) composite axis
    shard = _psum_scatter(flat.reshape(n_in, -1), tuple(in_axes))
    if pod_axis is not None:
        shard = _psum(shard, pod_axis)
    out = _all_gather(shard, tuple(in_axes))
    return out.reshape(shape)


def tree_allreduce_local(x: Array, *, pod_axis: Optional[str],
                         in_axes: Sequence[str],
                         tree_bcast: bool = True) -> Array:
    """Paper-faithful all-reduce = agg (tree reduce to leader, Fig 4) +
    broadcast (tree, Fig 6) — what pPython programs compose from agg() and
    bcast().  ``tree_bcast=False`` uses the serialized initial broadcast
    (Fig 7) for the distribution half, so the 'serial' transport is a
    real P-1-round baseline rather than an alias of 'tree'."""
    bcast = tree_bcast_axis if tree_bcast else serial_bcast_axis
    for a in in_axes:
        x = tree_reduce_axis(x, a)
    if pod_axis is not None:
        x = tree_reduce_axis(x, pod_axis)
        x = bcast(x, pod_axis)
    for a in in_axes:
        x = bcast(x, a)
    return x
