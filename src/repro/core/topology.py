"""Rank-tree schedules for the paper's node-aware collectives.

pPython organizes collectives into two hierarchy levels (in-node /
off-node, paper Figs 4 & 6) with a binary tree inside each level.  On the
TPU mesh the levels are the mesh axes themselves: ``pod`` is the paper's
"off-node" (slow DCI) level and ``data``/``model`` the "in-node" (ICI)
level.  A binary tree over a composite level is the composition of
per-axis binary trees, so all schedules below are per-axis and the
collective layer chains them.

A *schedule* is a list of rounds; each round is a list of (src, dst) rank
pairs — directly consumable by ``lax.ppermute``.
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

Round = List[Tuple[int, int]]


def _ceil_log2(n: int) -> int:
    return max(0, (n - 1).bit_length())


def tree_bcast_rounds(n: int, root: int = 0) -> List[Round]:
    """Binary-tree broadcast (paper Fig 6): after round r, 2^(r+1) ranks
    hold the data.  Ranks are rotated so ``root`` is logical 0."""
    rounds: List[Round] = []
    have = 1
    while have < n:
        rnd: Round = []
        for i in range(have):
            j = i + have
            if j < n:
                rnd.append((((i + root) % n), ((j + root) % n)))
        rounds.append(rnd)
        have *= 2
    return rounds


def serial_bcast_rounds(n: int, root: int = 0) -> List[Round]:
    """The paper's *initial* broadcast: root sends to each rank in turn
    (P-1 serialized rounds; the Fig 7 'initial implementation')."""
    return [[(root, (root + i) % n)] for i in range(1, n)]


def tree_gather_rounds(n: int, root: int = 0) -> List[Round]:
    """Binary-tree gather to ``root`` (paper Fig 4 aggregation): the
    reverse of broadcast; at round r, ranks odd in units of 2^(r+1) send
    their accumulated block to their even partner."""
    rounds: List[Round] = []
    step = 1
    while step < n:
        rnd: Round = []
        for i in range(0, n, 2 * step):
            j = i + step
            if j < n:
                rnd.append((((j + root) % n), ((i + root) % n)))
        rounds.append(rnd)
        step *= 2
    return rounds


def serial_gather_rounds(n: int, root: int = 0) -> List[Round]:
    return [[((root + i) % n, root)] for i in range(1, n)]


def ring_rounds(n: int, shift: int = 1) -> List[Round]:
    return [[(i, (i + shift) % n) for i in range(n)]]


def pairwise_alltoall_rounds(n: int, serial: bool = False
                             ) -> List[Tuple[str, object, Round]]:
    """Schedules for the pairwise-exchange all-to-all along one axis.

    Returns ``(kind, arg, perm)`` rounds:

    * ``("xor", k, perm)``   — power-of-two n: round k pairs rank i with
      i^k (disjoint partner pairs, every link busy).  Ascending k means
      nearest neighbours exchange first — composed per-axis by the
      transport layer (in-axes before the pod axis), this is the
      node-aware ordering: all ICI rounds complete before any DCI round.
    * ``("rot", k, perm)``   — general n: round k shifts by k (send to
      i+k, receive from i-k), the classic n-1-round rotation exchange.
    * ``("pair", (s, d), perm)`` — ``serial=True``: one (src, dst) pair
      per round, n*(n-1) rounds — the all-to-all analogue of the paper's
      *initial* serialized broadcast (Fig 7 baseline).
    """
    if serial:
        return [("pair", (s, d), [(s, d)])
                for s in range(n) for d in range(n) if s != d]
    if n & (n - 1) == 0:
        return [("xor", k, [(i, i ^ k) for i in range(n)])
                for k in range(1, n)]
    return [("rot", k, [(i, (i + k) % n) for i in range(n)])
            for k in range(1, n)]


def bcast_round_count(n: int, tree: bool) -> int:
    return _ceil_log2(n) if tree else max(n - 1, 0)


def two_level_cost(n_local: int, n_global: int, bytes_per_rank: float,
                   ici_bw: float, dci_bw: float, tree: bool = True
                   ) -> float:
    """Analytic broadcast-time model used by the benchmark harness to
    extrapolate the paper's 2..768-rank sweep to pod scale: per-level
    round count x bytes / level bandwidth."""
    r_local = bcast_round_count(n_local, tree)
    r_global = bcast_round_count(n_global, tree)
    return (r_local * bytes_per_rank / ici_bw
            + r_global * bytes_per_rank / dci_bw)
