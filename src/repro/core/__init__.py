"""PGAS core: the paper's contribution (maps, distributed arrays,
node-aware tree collectives) as composable JAX modules."""
from repro.core import collectives, topology
from repro.core.dmap import Dmap
from repro.core.dmat import Dmat, ones, rand, zeros

__all__ = ["Dmap", "Dmat", "zeros", "ones", "rand", "collectives",
           "topology"]
