"""Optimizers implemented from scratch (no optax in this environment):
AdamW and factored Adafactor (for the >=400B MoEs where full Adam state
would not fit a 256-chip pod), plus global-norm clipping and a
warmup-cosine schedule.

State pytrees mirror the parameter tree leaf-for-leaf so the partition
specs derive mechanically from the parameter specs (``opt_pspecs``):
Adam moments inherit the param spec; Adafactor's factored moments drop
the reduced dim's spec entry — i.e. optimizer state is sharded exactly
as far as the parameters are (ZeRO-style when FSDP is on).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # adafactor
    decay_rate: float = 0.8
    momentum: bool = False


def warmup_cosine(cfg: OptimizerConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * cfg.peak_lr * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptimizerConfig, grads, state, params, lr: Array):
    c = state["count"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** c.astype(jnp.float32)
    bc2 = 1 - b2 ** c.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        step = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

    p_l, tdef = jax.tree.flatten(params)
    g_l = tdef.flatten_up_to(grads)
    m_l = tdef.flatten_up_to(state["m"])
    v_l = tdef.flatten_up_to(state["v"])
    res = [upd(g, m, v, p) for g, m, v, p in zip(g_l, m_l, v_l, p_l)]
    new_params = tdef.unflatten([r[0] for r in res])
    m = tdef.unflatten([r[1] for r in res])
    v = tdef.unflatten([r[2] for r in res])
    return new_params, {"m": m, "v": v, "count": c}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments over the last two dims)
# ---------------------------------------------------------------------------

def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 2 and p.shape[-2] >= 2


def adafactor_init(params):
    def slot(p):
        if _factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"slots": jax.tree.map(slot, params),
            "count": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptimizerConfig, grads, state, params, lr: Array):
    c = state["count"] + 1
    beta2 = 1.0 - c.astype(jnp.float32) ** -cfg.decay_rate

    def upd(g, slot, p):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if _factored(p):
            vr = beta2 * slot["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * slot["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.mean(vr, axis=-1, keepdims=True)
            rhat = vr / jnp.maximum(denom, 1e-30)
            u = g / (jnp.sqrt(rhat)[..., None] * jnp.sqrt(vc)[..., None, :]
                     + 1e-30)
            new_slot = {"vr": vr, "vc": vc}
        else:
            v = beta2 * slot["v"] + (1 - beta2) * g2
            u = g / (jnp.sqrt(v) + 1e-30)
            new_slot = {"v": v}
        # RMS-based update clipping (Adafactor d=1)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_slot

    p_l, tdef = jax.tree.flatten(params)
    g_l = tdef.flatten_up_to(grads)
    s_l = tdef.flatten_up_to(state["slots"])
    res = [upd(g, s, p) for g, s, p in zip(g_l, s_l, p_l)]
    new_params = tdef.unflatten([r[0] for r in res])
    slots = tdef.unflatten([r[1] for r in res])
    return new_params, {"slots": slots, "count": c}


# ---------------------------------------------------------------------------
# facade + partition specs
# ---------------------------------------------------------------------------

def opt_init(cfg: OptimizerConfig, params):
    return adafactor_init(params) if cfg.name == "adafactor" \
        else adamw_init(params)


def opt_update(cfg: OptimizerConfig, grads, state, params, step: Array):
    """Returns (new_params, new_state, lr) — the schedule value is
    surfaced so train-step metrics report the lr actually applied."""
    lr = warmup_cosine(cfg, step)
    if cfg.name == "adafactor":
        new_params, new_state = adafactor_update(cfg, grads, state, params,
                                                 lr)
    else:
        new_params, new_state = adamw_update(cfg, grads, state, params, lr)
    return new_params, new_state, lr


def opt_pspecs(cfg: OptimizerConfig, param_pspecs, abstract_params):
    def full(spec):
        return spec

    if cfg.name != "adafactor":
        return {"m": jax.tree.map(full, param_pspecs,
                                  is_leaf=lambda x: isinstance(x, P)),
                "v": jax.tree.map(full, param_pspecs,
                                  is_leaf=lambda x: isinstance(x, P)),
                "count": P()}

    def slot_spec(spec, p):
        t = tuple(spec) + (None,) * (p.ndim - len(tuple(spec)))
        if _factored(p):
            return {"vr": P(*t[:-1]), "vc": P(*t[:-2], t[-1])}
        return {"v": P(*t[:p.ndim])}

    slots = jax.tree.map(slot_spec, param_pspecs, abstract_params,
                         is_leaf=lambda x: isinstance(x, P))
    return {"slots": slots, "count": P()}
