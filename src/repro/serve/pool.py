"""Host-side block pool for the paged KV cache.

The device side (``repro.models.cache``) stores paged entries as a
shared physical pool of fixed-size blocks plus a per-slot block table;
this module owns the *allocation policy* for that table.  One
``BlockPool`` serves every paged entry of an engine cache: entries
allocate in lockstep (a slot's logical block i maps to the same
physical block index in each entry's pool), so a single host table is
uploaded to all of them whenever it changes.

Two-level accounting keeps leasing deadlock-free:

* ``reserve(slot, tokens)`` — at admission, *commit* the worst-case
  block count for the request (prompt + max_new tokens).  Admission is
  refused (``can_reserve`` False) unless every active slot could still
  grow to its commitment, so ``ensure`` can never fail mid-flight.
* ``ensure(slot, length)`` — before each dispatch, *lease* just enough
  physical blocks to cover ``length`` tokens.  This is what actually
  consumes pool blocks: ``high_water`` tracks the peak leased count,
  which is the engine's true memory footprint (proportional to live
  tokens, not to ``slots * max_len`` as with dense rings).
"""
from __future__ import annotations

from typing import List

import numpy as np


class PoolExhausted(RuntimeError):
    """A lease was requested beyond the slot's admission commitment."""


class BlockPool:
    def __init__(self, num_blocks: int, block_size: int, slots: int,
                 max_len: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.slots = slots
        self.max_blocks_per_slot = -(-max_len // block_size)
        #: per-slot logical -> physical block map; -1 = unleased.  The
        #: engine uploads this to every paged cache entry when ``dirty``.
        self.table = np.full((slots, self.max_blocks_per_slot), -1, np.int32)
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._leased = np.zeros((slots,), np.int32)
        self._commit = np.zeros((slots,), np.int32)
        self._committed = 0
        self.high_water = 0
        self.dirty = False

    # ------------------------------------------------------------- queries
    def blocks_for(self, tokens: int) -> int:
        return -(-max(tokens, 0) // self.block_size)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def committed(self) -> int:
        return self._committed

    def can_reserve(self, tokens: int) -> bool:
        """Could a request needing ``tokens`` cache lines be admitted now
        without risking mid-flight exhaustion?"""
        need = min(self.blocks_for(tokens), self.max_blocks_per_slot)
        return self._committed + need <= self.num_blocks

    # ------------------------------------------------------------ mutation
    def reserve(self, slot: int, tokens: int) -> None:
        """Commit slot's worst case (called once, at admission)."""
        if self._commit[slot]:
            raise ValueError(f"slot {slot} already reserved")
        need = min(self.blocks_for(tokens), self.max_blocks_per_slot)
        if self._committed + need > self.num_blocks:
            raise PoolExhausted(
                f"cannot commit {need} blocks: {self._committed}/"
                f"{self.num_blocks} already committed")
        self._commit[slot] = need
        self._committed += need

    def ensure(self, slot: int, length: int) -> None:
        """Lease blocks so slot can hold ``length`` tokens."""
        need = self.blocks_for(length)
        if need > self._commit[slot]:
            raise PoolExhausted(
                f"slot {slot} needs {need} blocks but committed only "
                f"{int(self._commit[slot])} at admission")
        while self._leased[slot] < need:
            blk = self._free.pop()      # cannot fail: leases <= commits
            self.table[slot, self._leased[slot]] = blk
            self._leased[slot] += 1
            self.dirty = True
        self.high_water = max(self.high_water, self.used_blocks)

    def release(self, slot: int) -> None:
        """Return slot's blocks to the pool and drop its commitment."""
        for i in range(int(self._leased[slot])):
            self._free.append(int(self.table[slot, i]))
        if self._leased[slot]:
            self.dirty = True
        self.table[slot, :] = -1
        self._leased[slot] = 0
        self._committed -= int(self._commit[slot])
        self._commit[slot] = 0
