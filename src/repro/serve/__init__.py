"""Production serving subsystem: paged KV cache, continuous batching,
async engine loop.  See repro/serve/README.md."""
from repro.serve.engine import Engine, Request, ServeResult
from repro.serve.pool import BlockPool, PoolExhausted
from repro.serve.scheduler import Scheduler, agree_admission_count

__all__ = ["Engine", "Request", "ServeResult", "BlockPool",
           "PoolExhausted", "Scheduler", "agree_admission_count"]
