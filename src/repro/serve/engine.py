"""Serving engine: paged KV cache + continuous batching + async overlap.

The engine glues three pieces (see repro/serve/README.md):

* :class:`~repro.serve.pool.BlockPool` — host-side lease accounting for
  the paged KV cache (``cache_mode="paged"``, the default for
  attention-only architectures): slots lease fixed-size blocks on
  demand instead of reserving ``slots * max_len`` dense rings.
* :class:`~repro.serve.scheduler.Scheduler` — continuous batching:
  requests are admitted into free slots *between* ticks, and each tick
  is one jitted dispatch (``Model.serve_step`` + in-jit batched
  sampling, cache buffers donated) in which every row independently
  carries a prefill chunk, a decode token, or nothing.
* an async loop — dispatches tick t+1 before processing tick t's
  sampled tokens, so host-side bookkeeping overlaps device work.
  Decode ticks read their input token from a device-resident
  next-token buffer (updated inside the previous dispatch), so no
  host round-trip sits on the critical path.  Length-based completion
  is host-predictable; EOS detection lags one tick — the speculative
  extra token is discarded (epoch-guarded) and the slot released.

Cache modes:

* ``paged``  — batched direct-write prefill + paged full-length
  entries.  Requires an attention-only architecture (no MoE, no
  recurrent state, no cross-attention): padded rows in a shared
  dispatch are provably inert only for the masked-scatter KV path.
* ``dense``  — same batched path over dense rings (the equivalence
  reference for paged, and the right choice when ``max_len`` is small).
* ``legacy`` — isolated batch=1 chunked prefill scattered into the
  slot (the pre-paged path), batched decode.  Automatically selected
  for MoE / recurrent / encoder-decoder architectures, where padded
  prefill rows would corrupt per-slot recurrent state or couple slots
  through expert capacity.

Cross-host: admission goes through a Communicator agg+bcast agreement
round (:func:`~repro.serve.scheduler.agree_admission_count`); load and
drain are Communicator barriers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.comms import Communicator
from repro.configs.base import ArchConfig
from repro.models import cache as cache_lib
from repro.models.model import Model
from repro.serve.pool import BlockPool
from repro.serve.scheduler import Scheduler, TickPlan, agree_admission_count

_LOAD_MSG = "Engine.load() must be called before admission"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    eos_id: Optional[int] = None  # stop token (detected one tick late)
    out_tokens: Optional[List[int]] = None


class ServeResult(dict):
    """``{rid: [tokens]}`` for completed requests, plus:

    * ``truncated`` — True when ``max_steps`` hit before the queue
      drained (the old engine silently dropped this);
    * ``unfinished`` — ``{rid: partial tokens}`` for in-flight and
      never-admitted requests at truncation;
    * ``metrics`` — ``{rid: {arrival_s, ttft_s, done_s, tokens}}``
      (host-observed; TTFT includes the one-tick pipeline lag).
    """

    def __init__(self, done, truncated: bool, unfinished, metrics):
        super().__init__(done)
        self.truncated = truncated
        self.unfinished = dict(unfinished)
        self.metrics = dict(metrics)


def _supports_batched(cfg: ArchConfig) -> bool:
    """Archs whose padded rows are inert in a shared prefill dispatch."""
    return not (cfg.num_experts or cfg.xlstm_pattern
                or cfg.family == "hybrid" or cfg.encoder_layers
                or cfg.xattn_every)


class Engine:
    def __init__(self, cfg: ArchConfig, mesh: Mesh, slots: int,
                 max_len: int, seed: int = 0, cache_mode: str = "auto",
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 policy: str = "conservative", overlap: bool = True):
        self.cfg = cfg
        self.model = Model(cfg, mesh)
        self.comm = Communicator.for_mesh(mesh)
        self.slots = slots
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self.overlap = overlap
        batched_ok = _supports_batched(cfg)
        if cache_mode == "auto":
            cache_mode = "paged" if batched_ok else "legacy"
        if cache_mode in ("paged", "dense") and not batched_ok:
            raise ValueError(
                f"cache_mode={cache_mode!r} needs the batched prefill "
                f"path, unavailable for arch {cfg.name!r} (recurrent/"
                f"MoE/enc-dec); use cache_mode='legacy'")
        if cache_mode not in ("paged", "dense", "legacy"):
            raise ValueError(f"unknown cache_mode {cache_mode!r}")
        self.cache_mode = cache_mode
        self.block_size = block_size
        m_blocks = -(-max_len // block_size)
        self.num_blocks = slots * m_blocks if num_blocks is None \
            else num_blocks
        self.sched = Scheduler(slots, cfg.prefill_chunk, policy)
        self.pool: Optional[BlockPool] = None
        self.params = None
        self.cache = None
        self.next_buf = None
        self.temps = np.zeros((slots,), np.float32)
        self.requests: Dict[int, Request] = {}
        self._done: Dict[int, List[int]] = {}
        self._metrics: Dict[int, dict] = {}
        self._arrival: Dict[int, float] = {}
        self._reset_mask = np.zeros((slots,), bool)
        donate = jax.default_backend() != "cpu"
        self._dispatch_fn = jax.jit(
            self._dispatch_body, donate_argnums=(7, 8) if donate else ())
        self._reset_fn = jax.jit(
            self.model.reset_cache_slots,
            donate_argnums=(0,) if donate else ())
        self._extend = jax.jit(self.model.extend)
        self._scatter = jax.jit(self._scatter_body)
        self._sample1 = jax.jit(self._sample1_body)

    # ------------------------------------------------------------------ load
    def load(self, params) -> None:
        self.params = params
        if self.cache_mode == "paged":
            spec = cache_lib.PageSpec(self.block_size, self.num_blocks)
            self.cache = self.model.init_cache(self.slots, self.max_len,
                                               paged=spec)
            self.pool = BlockPool(self.num_blocks, self.block_size,
                                  self.slots, self.max_len)
        else:
            self.cache = self.model.init_cache(self.slots, self.max_len)
            self.pool = None
        self.next_buf = jnp.zeros((self.slots,), jnp.int32)
        # every rank must hold weights + cache before admission starts
        self.comm.sync()

    # ----------------------------------------------------------- jit bodies
    def _dispatch_body(self, params, tokens, use_next, starts, lengths,
                      temps, key, next_buf, cache):
        """One tick: serve_step + batched sampling, all in one dispatch.
        Rows with ``use_next`` read their (single) token from the device
        next-token buffer; idle rows (length 0) touch nothing."""
        first = jnp.arange(tokens.shape[1], dtype=jnp.int32)[None] == 0
        tok = jnp.where(use_next[:, None] & first, next_buf[:, None],
                        tokens)
        logits, cache = self.model.serve_step(params, tok, starts,
                                              lengths, cache)
        lg = logits[:, -1].astype(jnp.float32)                    # (B, V)
        greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        drawn = jax.random.categorical(
            key, lg / jnp.maximum(temps, 1e-6)[:, None]).astype(jnp.int32)
        nxt = jnp.where(temps > 0, drawn, greedy)
        next_buf = jnp.where(lengths > 0, nxt, next_buf)
        return nxt, next_buf, cache

    def _sample1_body(self, lg, temp, key):
        """Single-row sampler for the legacy path's prefill logits —
        same formula as the batched tick sampler."""
        lg = lg.reshape(-1).astype(jnp.float32)
        greedy = jnp.argmax(lg).astype(jnp.int32)
        drawn = jax.random.categorical(
            key, lg / jnp.maximum(temp, 1e-6)).astype(jnp.int32)
        return jnp.where(temp > 0, drawn, greedy)

    def _scatter_body(self, big, one, slot):
        """Write a batch=1 dense cache into batch row ``slot``.  'pos'
        leaves carry batch at dim 0, tensor leaves at dim 1."""
        out = {}
        for name, ent in big.items():
            out[name] = {}
            for k, v in ent.items():
                o = one[name][k]
                if k == "pos":
                    out[name][k] = v.at[slot].set(o[0])
                else:
                    out[name][k] = v.at[:, slot].set(o[:, 0])
        return out

    # ------------------------------------------------------------ admission
    def _cap_for(self, req: Request) -> int:
        p = int(len(req.prompt))
        if p + 1 > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {p} does not fit "
                f"max_len {self.max_len} (need prompt + 1)")
        return min(req.max_new_tokens, self.max_len - p)

    def _admittable(self, reqs: List[Request]) -> int:
        """How many of ``reqs`` (in order) this rank can admit now."""
        free = len(self.sched.free_slots())
        n, extra = 0, 0
        for req in reqs[:free]:
            if self.pool is not None:
                worst = min(self.pool.blocks_for(len(req.prompt)
                                                 + self._cap_for(req)),
                            self.pool.max_blocks_per_slot)
                if self.pool.committed + extra + worst > self.pool.num_blocks:
                    break
                extra += worst
            n += 1
        return n

    def admit(self, req: Request, arrival_s: float = 0.0) -> bool:
        """Admit one request into a free slot; False when full.  Part of
        the old per-request API — run_to_completion/run_trace admit
        through the same path with cross-host agreement."""
        if self.params is None:
            raise RuntimeError(_LOAD_MSG)
        if self._admittable([req]) < 1:
            return False
        self._admit_one(req, arrival_s)
        return True

    def _admit_one(self, req: Request, arrival_s: float) -> None:
        slot = self.sched.free_slots()[0]
        cap = self._cap_for(req)
        req.out_tokens = []
        self.requests[req.rid] = req
        self._arrival[req.rid] = arrival_s
        if cap <= 0:                      # nothing to generate
            self._finalize(req.rid, arrival_s)
            return
        st = self.sched.assign(slot, req.rid, np.asarray(req.prompt),
                               cap, req.temperature, req.eos_id)
        self.temps[slot] = req.temperature
        if self.pool is not None:
            self.pool.reserve(slot, st.prompt_len + cap)
        if self.cache_mode == "legacy":
            self._legacy_prefill(slot, st)

    def _legacy_prefill(self, slot: int, st) -> None:
        """Isolated batch=1 chunked prefill, scattered into the slot —
        blocking, but safe for recurrent/MoE archs where padded rows in
        a shared dispatch are not inert."""
        prompt = st.prompt
        chunk = self.cfg.prefill_chunk
        cache1 = self.model.init_cache(1, self.max_len)
        pos, logits = 0, None
        while pos < len(prompt):
            n = chunk if len(prompt) - pos >= chunk else 1
            tok = jnp.asarray(prompt[pos:pos + n][None])
            start = jnp.asarray([pos], jnp.int32)
            logits, cache1 = self._extend(self.params, tok, start, cache1,
                                          {})
            pos += n
        self.cache = self._scatter(self.cache, cache1,
                                   jnp.asarray(slot, jnp.int32))
        self.key, sub = jax.random.split(self.key)
        tok0 = self._sample1(logits, jnp.asarray(st.temperature), sub)
        self.next_buf = self.next_buf.at[slot].set(tok0)
        st.fed = st.prompt_len
        st.sampled = 1
        self._record(slot, st.epoch, 0, int(tok0), self._now())

    def _admit_arrived(self, queue: List[Tuple[float, Request]],
                       now: float) -> None:
        """Admit as many arrived requests as the whole fleet agrees on."""
        arrived = [r for (t, r) in queue if t <= now]
        if not arrived:
            return
        n = self._admittable(arrived)
        n = agree_admission_count(self.comm, n)
        for req in arrived[:n]:
            idx = next(i for i, (_, r) in enumerate(queue) if r is req)
            arr, _ = queue.pop(idx)
            self._admit_one(req, arr)

    # ----------------------------------------------------------------- ticks
    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _pre_dispatch(self, plan: TickPlan) -> None:
        if self._reset_mask.any():
            self.cache = self._reset_fn(self.cache,
                                        jnp.asarray(self._reset_mask))
            self._reset_mask[:] = False
        if self.pool is not None:
            for i in range(self.slots):
                if plan.lengths[i] > 0:
                    self.pool.ensure(i, int(plan.starts[i])
                                     + int(plan.lengths[i]))
            if self.pool.dirty:
                bt = jnp.asarray(self.pool.table)
                for ent in self.cache.values():
                    if "btab" in ent:
                        ent["btab"] = bt
                self.pool.dirty = False

    def _dispatch(self, plan: TickPlan):
        self._pre_dispatch(plan)
        self.key, sub = jax.random.split(self.key)
        nxt, self.next_buf, self.cache = self._dispatch_fn(
            self.params, jnp.asarray(plan.tokens),
            jnp.asarray(plan.use_next), jnp.asarray(plan.starts),
            jnp.asarray(plan.lengths), jnp.asarray(self.temps), sub,
            self.next_buf, self.cache)
        return nxt

    def _finish(self, plan: TickPlan, nxt) -> Dict[int, int]:
        """Host bookkeeping for a completed tick (blocks on the device)."""
        toks = np.asarray(nxt)
        now = self._now()
        out: Dict[int, int] = {}
        for slot, epoch, gidx in plan.samples:
            st = self.sched.states[slot]
            if st is None or st.epoch != epoch:
                continue              # slot released mid-flight (EOS)
            tok = int(toks[slot])
            out[st.rid] = tok
            self._record(slot, epoch, gidx, tok, now)
        return out

    def _record(self, slot: int, epoch: int, gidx: int, tok: int,
                now: float) -> None:
        st = self.sched.states[slot]
        req = self.requests[st.rid]
        req.out_tokens.append(tok)
        st.recorded = gidx + 1
        if gidx == 0:
            self._metrics[st.rid] = {
                "arrival_s": self._arrival[st.rid],
                "ttft_s": now - self._arrival[st.rid]}
        hit_eos = st.eos_id is not None and tok == st.eos_id
        if hit_eos:
            st.done = True
        if hit_eos or st.recorded >= st.cap:
            self._release(slot)
            self._finalize(st.rid, now)

    def _release(self, slot: int) -> None:
        if self.pool is not None:
            self.pool.release(slot)
        self._reset_mask[slot] = True
        self.temps[slot] = 0.0
        self.sched.release(slot)

    def _finalize(self, rid: int, now: float) -> None:
        req = self.requests.pop(rid)
        self._done[rid] = req.out_tokens
        m = self._metrics.setdefault(
            rid, {"arrival_s": self._arrival[rid], "ttft_s": None})
        m["done_s"] = now
        m["tokens"] = len(req.out_tokens)
        self._arrival.pop(rid, None)

    def step(self) -> Dict[int, int]:
        """Plan + dispatch + finish one tick synchronously; returns
        ``{rid: sampled token}`` for the rows that sampled this tick."""
        if self.params is None:
            raise RuntimeError(_LOAD_MSG)
        plan = self.sched.plan()
        if plan is None:
            return {}
        return self._finish(plan, self._dispatch(plan))

    # ------------------------------------------------------------ run loops
    def run_to_completion(self, reqs: List[Request],
                          max_steps: int = 10_000) -> ServeResult:
        """Serve ``reqs`` (all available immediately) to completion."""
        return self.run_trace(reqs, [0.0] * len(reqs), max_steps=max_steps)

    def run_trace(self, reqs: List[Request], arrivals_s: List[float],
                  max_steps: int = 10_000) -> ServeResult:
        """Serve a timed trace: request i becomes admittable once
        ``arrivals_s[i]`` seconds have elapsed.  Overlapped loop: tick
        t+1 is dispatched before tick t's tokens are read back."""
        if self.params is None:
            raise RuntimeError(_LOAD_MSG)
        if len(reqs) != len(arrivals_s):
            raise ValueError("one arrival time per request")
        if self.pool is not None:
            for r in reqs:   # reject never-admittable requests up front
                worst = self.pool.blocks_for(len(r.prompt)
                                             + self._cap_for(r))
                worst = min(worst, self.pool.max_blocks_per_slot)
                if worst > self.pool.num_blocks:
                    raise ValueError(
                        f"request {r.rid} needs {worst} blocks but the "
                        f"pool holds {self.pool.num_blocks}")
        self._t0 = time.perf_counter()
        self._done, self._metrics = {}, {}
        queue = sorted(zip(arrivals_s, reqs), key=lambda p: p[0])
        inflight = None
        steps = 0
        while steps < max_steps:
            self._admit_arrived(queue, self._now())
            plan = self.sched.plan()
            if plan is None:
                if inflight is not None:
                    self._finish(*inflight)     # may free slots
                    inflight = None
                    continue
                if queue:
                    wait = queue[0][0] - self._now()
                    if wait > 0:
                        time.sleep(min(wait, 1e-3))
                    continue
                break
            nxt = self._dispatch(plan)
            steps += 1
            if inflight is not None:
                self._finish(*inflight)
            inflight = (plan, nxt)
            if not self.overlap:
                self._finish(*inflight)
                inflight = None
        if inflight is not None:
            self._finish(*inflight)
        self.comm.sync()       # drain: all ranks idle before returning
        unfinished = {st.rid: list(self.requests[st.rid].out_tokens)
                      for _, st in self.sched.active()}
        unfinished.update({r.rid: [] for _, r in queue})
        truncated = bool(unfinished) and steps >= max_steps
        return ServeResult(self._done, truncated, unfinished,
                           self._metrics)

    _t0 = 0.0
