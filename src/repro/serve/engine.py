"""Serving engine: chunked prefill + batched decode with slot management.

A light continuous-batching engine over the Model API:
  * fixed number of ``slots`` (the decode batch);
  * requests are admitted into free slots; prefill runs chunked (bounded
    activation footprint — the same ``extend`` path the dry-run lowers);
  * one jit'd decode step advances every active slot by a token;
  * per-slot positions mean requests of different lengths coexist (the
    cache machinery masks by true token positions);
  * greedy or temperature sampling with an explicit PRNG key.

The multi-host production layout shards slots over the batch axes and
the KV cache per partition.py; this engine is what examples/serve_lm.py
and the decode benchmarks drive.  Host-side admission control is
per-process, so cross-host agreement points (weights loaded, drain)
go through the mesh-bound ``Communicator`` barrier rather than ad-hoc
blocking on arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.comms import Communicator
from repro.configs.base import ArchConfig
from repro.models.model import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_tokens: Optional[List[int]] = None


class Engine:
    def __init__(self, cfg: ArchConfig, mesh: Mesh, slots: int,
                 max_len: int, seed: int = 0):
        self.cfg = cfg
        self.model = Model(cfg, mesh)
        self.comm = Communicator.for_mesh(mesh)
        self.slots = slots
        self.max_len = max_len
        self.params = None
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(self.model.decode_step)
        self._extend = jax.jit(self.model.extend, static_argnames=())
        self.cache = None
        self.positions = np.zeros((slots,), np.int32)
        self.active = np.zeros((slots,), bool)
        self.requests: Dict[int, Request] = {}
        self.slot_of: Dict[int, int] = {}

    def load(self, params) -> None:
        self.params = params
        self.cache = self.model.init_cache(self.slots, self.max_len)
        # every rank must hold weights + cache before admission starts
        self.comm.sync()

    # ------------------------------------------------------------- admit
    def _scatter_slot(self, big, one, slot: int):
        """Write a batch=1 cache into batch slot ``slot`` of the engine
        cache.  'pos' leaves carry batch at dim 0, tensor leaves at dim 1."""
        def put(b, o):
            if b.ndim == o.ndim and o.shape[0] == 1 and b.shape[0] == self.slots:
                return b.at[slot].set(o[0])            # pos: (B, W)
            return b.at[:, slot].set(o[:, 0])          # (count, B, ...)
        return jax.tree.map(put, big, one)

    def admit(self, req: Request) -> bool:
        """Prefill the request in an isolated batch=1 cache (chunked, with
        a single-token tail), then scatter it into a free slot."""
        free = np.where(~self.active)[0]
        if free.size == 0:
            return False
        slot = int(free[0])
        self.active[slot] = True
        self.requests[req.rid] = req
        self.slot_of[req.rid] = slot
        req.out_tokens = []
        prompt = req.prompt.astype(np.int32)
        chunk = self.cfg.prefill_chunk
        cache1 = self.model.init_cache(1, self.max_len)
        pos = 0
        while pos < len(prompt):
            n = chunk if len(prompt) - pos >= chunk else 1
            tok = jnp.asarray(prompt[pos:pos + n][None])
            start = jnp.asarray([pos], jnp.int32)
            _, cache1 = self._extend(self.params, tok, start, cache1, {})
            pos += n
        self.cache = self._scatter_slot(self.cache, cache1, slot)
        self.positions[slot] = len(prompt)
        return True

    # ------------------------------------------------------------- decode
    def step(self) -> Dict[int, int]:
        """One decode step for all active slots; returns {rid: token}."""
        if not self.active.any():
            return {}
        tok = np.zeros((self.slots, 1), np.int32)
        for rid, slot in self.slot_of.items():
            req = self.requests[rid]
            prev = req.out_tokens[-1] if req.out_tokens else \
                int(req.prompt[-1])
            tok[slot, 0] = prev
        logits, self.cache = self._decode(
            self.params, jnp.asarray(tok), jnp.asarray(self.positions),
            self.cache)
        out: Dict[int, int] = {}
        logits = np.asarray(logits[:, -1].astype(jnp.float32))
        done: List[int] = []
        for rid, slot in self.slot_of.items():
            req = self.requests[rid]
            if req.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                nxt = int(jax.random.categorical(
                    sub, jnp.asarray(logits[slot]) / req.temperature))
            else:
                nxt = int(logits[slot].argmax())
            req.out_tokens.append(nxt)
            self.positions[slot] += 1
            out[rid] = nxt
            if len(req.out_tokens) >= req.max_new_tokens \
                    or self.positions[slot] >= self.max_len - 1:
                done.append(rid)
        for rid in done:
            slot = self.slot_of.pop(rid)
            self.active[slot] = False
            self.positions[slot] = 0
        return out

    def run_to_completion(self, reqs: List[Request], max_steps: int = 10_000
                          ) -> Dict[int, List[int]]:
        pending = list(reqs)
        results: Dict[int, List[int]] = {}
        steps = 0
        while (pending or self.slot_of) and steps < max_steps:
            while pending and self.admit(pending[0]):
                pending.pop(0)
            self.step()
            steps += 1
            for rid in list(self.requests):
                if rid not in self.slot_of:
                    results[rid] = self.requests.pop(rid).out_tokens
        self.comm.sync()       # drain: all ranks idle before returning
        return results
