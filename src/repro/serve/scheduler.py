"""Continuous-batching scheduler: tick planning + admission agreement.

The scheduler owns host-side slot bookkeeping and turns it into *ticks*
— single jitted dispatches over the whole slot batch in which each row
independently carries a prefill chunk, one decode token, or nothing
(idle rows are masked out by ``lengths == 0``).  Two tick policies:

* ``conservative`` (default) — prefill chunks and decode tokens never
  share a dispatch: chunk ticks run at a fixed width
  ``cfg.prefill_chunk`` while decode rows idle; decode ticks are always
  width 1.  Every slot therefore sees exactly the same per-token
  computation it would see alone in the batch, which keeps greedy
  outputs bit-identical between solo and batched serving.
* ``mixed`` — decode rows join chunk ticks as single-token rows (their
  token is spliced from the device-resident next-token buffer inside
  the dispatch).  Fewer dispatches under mixed prefill/decode load, at
  the cost of ULP-level divergence (decode runs in chunk-mode attention
  with a different dispatch width).

Counters per slot (``SlotState``): ``fed`` tokens written to the KV
cache so far, ``sampled`` generated tokens whose sampling has been
*dispatched*, ``recorded`` generated tokens the host has actually seen.
With the engine's one-tick-deep pipeline, ``sampled`` runs ahead of
``recorded``; planning uses ``sampled`` (host-predictable), completion
uses ``recorded``.  ``epoch`` guards slot reuse: a tick's sample rows
remember the epoch they were planned against, and finish-processing
drops rows whose slot has since been released (e.g. the speculative
token dispatched in the tick after an EOS).

Cross-host admission goes through :func:`agree_admission_count`: each
rank proposes how many queued requests it can admit and a Communicator
agg+bcast round takes the fleet-wide minimum, so slot assignment stays
identical on every rank without ad-hoc host blocking.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.comms import Communicator


@dataclasses.dataclass
class SlotState:
    rid: int
    prompt: np.ndarray
    cap: int                       # generated-token budget (>= 1)
    temperature: float
    eos_id: Optional[int]
    epoch: int
    fed: int = 0                   # tokens written into the cache
    sampled: int = 0               # generated tokens dispatched
    recorded: int = 0              # generated tokens seen by the host
    done: bool = False             # no further ticks (EOS or cap)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def prefilling(self) -> bool:
        return self.fed < self.prompt_len

    @property
    def decode_ready(self) -> bool:
        return (not self.done and not self.prefilling
                and self.sampled < self.cap)


@dataclasses.dataclass
class TickPlan:
    """One dispatch: (B, width) token rows + which rows sample."""

    kind: str                       # "chunk" | "decode"
    width: int
    tokens: np.ndarray              # (B, width) int32 host tokens
    use_next: np.ndarray            # (B,) bool: row 0 token comes from the
                                    # device next-token buffer instead
    starts: np.ndarray              # (B,) int32
    lengths: np.ndarray             # (B,) int32 (0 = idle row)
    samples: List[Tuple[int, int, int]]  # (slot, epoch, gen_index)


class Scheduler:
    def __init__(self, slots: int, chunk: int, policy: str = "conservative"):
        if policy not in ("conservative", "mixed"):
            raise ValueError(f"unknown tick policy {policy!r}")
        self.n_slots = slots
        self.chunk = max(int(chunk), 1)
        self.policy = policy
        self.states: List[Optional[SlotState]] = [None] * slots
        self._epoch = 0

    # ---------------------------------------------------------------- slots
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.states) if s is None]

    def active(self) -> List[Tuple[int, SlotState]]:
        return [(i, s) for i, s in enumerate(self.states) if s is not None]

    def assign(self, slot: int, rid: int, prompt: np.ndarray, cap: int,
               temperature: float, eos_id: Optional[int]) -> SlotState:
        assert self.states[slot] is None
        self._epoch += 1
        st = SlotState(rid=rid, prompt=prompt.astype(np.int32), cap=cap,
                       temperature=temperature, eos_id=eos_id,
                       epoch=self._epoch)
        self.states[slot] = st
        return st

    def release(self, slot: int) -> None:
        self.states[slot] = None

    def has_work(self) -> bool:
        return any(s is not None and (s.prefilling or s.decode_ready)
                   for s in self.states)

    # ---------------------------------------------------------------- ticks
    def plan(self) -> Optional[TickPlan]:
        """Plan the next tick, advancing ``fed``/``sampled`` counters as
        if it were already dispatched (the engine dispatches it next)."""
        B = self.n_slots
        prefill = [(i, s) for i, s in self.active() if s.prefilling]
        decode = [(i, s) for i, s in self.active() if s.decode_ready]
        if not prefill and not decode:
            return None

        if prefill:
            C = self.chunk
            tokens = np.zeros((B, C), np.int32)
            starts = np.zeros((B,), np.int32)
            lengths = np.zeros((B,), np.int32)
            use_next = np.zeros((B,), bool)
            samples: List[Tuple[int, int, int]] = []
            for i, s in prefill:
                n = min(C, s.prompt_len - s.fed)
                tokens[i, :n] = s.prompt[s.fed:s.fed + n]
                starts[i] = s.fed
                lengths[i] = n
                s.fed += n
                if not s.prefilling:        # this chunk samples token 0
                    samples.append((i, s.epoch, 0))
                    s.sampled = 1
            if self.policy == "mixed":
                for i, s in decode:
                    starts[i] = s.fed
                    lengths[i] = 1
                    use_next[i] = True
                    samples.append((i, s.epoch, s.sampled))
                    s.fed += 1
                    s.sampled += 1
            return TickPlan("chunk", C, tokens, use_next, starts, lengths,
                            samples)

        tokens = np.zeros((B, 1), np.int32)
        starts = np.zeros((B,), np.int32)
        lengths = np.zeros((B,), np.int32)
        use_next = np.zeros((B,), bool)
        samples = []
        for i, s in decode:
            starts[i] = s.fed
            lengths[i] = 1
            use_next[i] = True
            samples.append((i, s.epoch, s.sampled))
            s.fed += 1
            s.sampled += 1
        return TickPlan("decode", 1, tokens, use_next, starts, lengths,
                        samples)


def agree_admission_count(comm: Communicator, n: int) -> int:
    """Fleet-wide admission agreement: every rank proposes how many
    queued requests it can admit this round; the agreed count is the
    minimum over ranks, computed on rank 0 (pPython's leader-on-rank-0
    agg convention) and broadcast back.  With identical SPMD host state
    this is the identity; it exists so a rank under local pressure
    (e.g. pool exhaustion) holds the whole fleet back coherently."""
    import jax.numpy as jnp

    if comm.size == 1:
        return n

    def body(x):
        allc = comm.agg(x, root=0)          # (size,) on root, 0 elsewhere
        return comm.bcast(jnp.min(allc), root=0)

    out = comm.run(body, jnp.asarray([n], jnp.int32))
    return int(out)
