"""Mini HLO analyzer for the roofline.

``compiled.cost_analysis()`` visits a while-loop body ONCE, but every
layer stack / microbatch loop / prefill chunk loop in this framework is a
`lax.scan` → XLA `while`, so raw cost numbers undercount by the trip
count.  This module parses the optimized HLO text into computations,
extracts while-loop trip counts (scan bounds are integer constants in the
loop condition), and propagates multipliers through the call graph to
produce loop-adjusted:

  * dot FLOPs        (2 * prod(result dims) * contraction size)
  * memory traffic   (sum of operand + result bytes of every non-trivial
                      instruction — post-fusion, so roughly HBM traffic)
  * collective bytes (per op kind, converted to per-device link bytes
                      with ring-algorithm factors, split ICI vs
                      cross-pod DCI)

All numbers are per-device (the HLO module is the SPMD per-device
program).  This is text-level analysis — a documented approximation, not
an XLA-internal cost model; EXPERIMENTS.md §Roofline records the
methodology.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CALL_RE = re.compile(r"(?:body|condition|calls|to_apply)=%?([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")
_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "iota", "broadcast",
                   "partition-id", "replica-id"}


def _shape_list(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype in _DTYPE_BYTES:
            out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str                   # operands + attrs (raw tail of the line)
    bytes_out: int


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr] = dataclasses.field(default_factory=list)
    is_entry: bool = False


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            if s.endswith("{") and "->" in s and " = " not in s:
                m = _COMP_HDR_RE.match(s)
                if m:
                    cur = Computation(m.group(2), is_entry=bool(m.group(1)))
            continue
        if s == "}" or s.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(s)
        if m:
            name, type_str, opcode, rest = m.groups()
            cur.instrs.append(
                Instr(name, type_str, opcode, rest, _type_bytes(type_str)))
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _trip_count(cond: Computation) -> int:
    """Scan bound = the max integer constant in the loop condition."""
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant":
            m = re.match(r"([\-\d]+)", ins.rest)
            if m:
                try:
                    best = max(best, int(m.group(1)))
                except ValueError:
                    pass
    return best


def _dot_flops(ins: Instr, symtab: Dict[str, Instr], params: Dict[str, int],
               shapes: Dict[str, List[int]]) -> float:
    res_dims = _shape_list(ins.type_str)
    n_out = 1
    for _, dims in res_dims[:1]:
        for d in dims:
            n_out *= d
    m = _LHS_CONTRACT_RE.search(ins.rest)
    contract = 1
    ops = _OPERAND_RE.findall(ins.rest.split(",")[0] + ","
                              + ins.rest.split(")")[0])
    lhs_shape = shapes.get(ops[0]) if ops else None
    if m and lhs_shape is not None:
        for idx in (int(i) for i in m.group(1).split(",") if i):
            if idx < len(lhs_shape):
                contract *= lhs_shape[idx]
    return 2.0 * n_out * contract


@dataclasses.dataclass
class Stats:
    dot_flops: float = 0.0
    bytes: float = 0.0
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)

    def add_collective(self, op, count, result_bytes, link, dci, mult=1.0):
        d = self.collectives.setdefault(
            op, {"count": 0.0, "result_bytes": 0.0, "link_bytes": 0.0,
                 "dci_link_bytes": 0.0})
        d["count"] += count * mult
        d["result_bytes"] += result_bytes * mult
        d["link_bytes"] += link * mult
        d["dci_link_bytes"] += dci * mult


def _group_size(rest: str) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 0


def _collective_link_bytes(op: str, b: float, n: int) -> float:
    n = max(n, 2)
    if op == "all-gather":
        return (n - 1) / n * b
    if op == "reduce-scatter":
        return (n - 1) * b
    if op == "all-reduce":
        return 2 * (n - 1) / n * b
    if op == "all-to-all":
        return (n - 1) / n * b
    return float(b)


def _crosses_pod(rest: str, n: int, pod_size: int, n_pods: int) -> bool:
    """Heuristic: a replica group spans pods iff its size is n_pods (pure
    pod-axis collective) or the full device count."""
    if n_pods <= 1:
        return False
    total = pod_size * n_pods
    return n == n_pods or n >= total


def analyze(text: str, pod_size: int = 256, n_pods: int = 1
            ) -> Dict[str, object]:
    comps = parse_module(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {"dot_flops": 0.0, "bytes": 0.0, "collectives": {},
                "loops": []}

    # global symbol/shape table (names are unique module-wide in practice)
    shapes: Dict[str, List[int]] = {}
    bytes_of: Dict[str, int] = {}
    for c in comps.values():
        for ins in c.instrs:
            sl = _shape_list(ins.type_str)
            if sl:
                shapes[ins.name] = sl[0][1]
            bytes_of[ins.name] = ins.bytes_out

    stats = Stats()
    loops: List[Tuple[str, int]] = []

    def visit(comp: Computation, mult: float, seen: Tuple[str, ...]):
        if comp.name in seen:
            return
        for ins in comp.instrs:
            if ins.opcode == "while":
                calls = _CALL_RE.findall(ins.rest)
                body = cond = None
                mbody = re.search(r"body=%?([\w\.\-]+)", ins.rest)
                mcond = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
                body = comps.get(mbody.group(1)) if mbody else None
                cond = comps.get(mcond.group(1)) if mcond else None
                trips = _trip_count(cond) if cond else 1
                loops.append((ins.name, trips))
                if body is not None:
                    visit(body, mult * trips, seen + (comp.name,))
                continue
            if ins.opcode in ("fusion", "call", "conditional"):
                # traverse for dot flops only (bytes counted at call site)
                for cname in _CALL_RE.findall(ins.rest):
                    sub = comps.get(cname)
                    if sub is not None and sub.name != comp.name:
                        for sins in sub.instrs:
                            if sins.opcode == "dot":
                                stats.dot_flops += mult * _dot_flops(
                                    sins, {}, {}, shapes)
            if ins.opcode == "dot":
                stats.dot_flops += mult * _dot_flops(ins, {}, {}, shapes)
            if ins.opcode.startswith(tuple(COLLECTIVE_OPS)) \
                    and not ins.opcode.endswith("-done"):
                op = next(o for o in COLLECTIVE_OPS
                          if ins.opcode.startswith(o))
                b = ins.bytes_out
                n = _group_size(ins.rest)
                link = _collective_link_bytes(op, b, n)
                dci = link if _crosses_pod(ins.rest, n, pod_size, n_pods) \
                    else 0.0
                stats.add_collective(op, 1, b, link, dci, mult)
            if ins.opcode not in _SKIP_BYTES_OPS:
                b = ins.bytes_out
                # operand reads (first parenthesised group of the tail)
                tail = ins.rest.split(")")[0]
                for ref in _OPERAND_RE.findall(tail):
                    b += bytes_of.get(ref, 0)
                stats.bytes += mult * b

    visit(entry, 1.0, ())
    link = sum(d["link_bytes"] for d in stats.collectives.values())
    dci = sum(d["dci_link_bytes"] for d in stats.collectives.values())
    return {"dot_flops": stats.dot_flops, "bytes": stats.bytes,
            "collectives": stats.collectives, "link_bytes": link,
            "dci_link_bytes": dci, "loops": loops}


# Back-compat helpers used by the dry-run
def parse_collectives(text: str, pod_boundary: int = 256):
    return analyze(text)["collectives"]


def totals(colls) -> Tuple[float, float]:
    link = sum(d["link_bytes"] for d in colls.values())
    dci = sum(d["dci_link_bytes"] for d in colls.values())
    return link, dci
