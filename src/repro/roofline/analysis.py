"""Roofline analysis over the dry-run artifacts.

Hardware model (TPU v5e, per assignment):
    peak   = 197e12  bf16 FLOP/s per chip
    hbm_bw = 819e9   B/s per chip
    ici_bw = 50e9    B/s per chip (per-link figure used as the per-chip
                     collective service rate, per the assignment formula)
    dci_bw = 6.25e9  B/s per chip cross-pod (assumption: pod DCN fabric
                     ~1/8 of ICI per chip; recorded so the cross-pod
                     sub-term is reproducible)

Terms (seconds, per step, from the loop-adjusted per-device HLO numbers):
    compute    = dot_flops / peak
    memory     = hbm_bytes / hbm_bw
    collective = link_bytes / ici_bw  (+ dci sub-term reported separately)

MODEL_FLOPS = 6 * N_active * tokens (train) or 2 * N_active * tokens
(prefill/decode), N_active excluding the token-embedding table.  The
ratio MODEL_FLOPS / (chips * dot_flops) measures how much compiled
compute is "useful" (remat recompute, attention quadratic terms and MoE
capacity slack all push it below 1).
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCI_BW = 6.25e9

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs.base import SHAPES, get_config
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.param_count(active_only=True) - cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def load_cells(result_dir: str = RESULT_DIR, mesh: str = "single"
               ) -> List[dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(result_dir, f"*__{mesh}.json"))):
        with open(f) as fh:
            out.append(json.load(fh))
    return out


def roofline_row(cell: dict) -> Optional[dict]:
    if cell.get("status") != "OK":
        return None
    chips = cell["devices"]
    comp = cell["flops_per_device"] / PEAK
    memt = cell["bytes_per_device"] / HBM_BW
    dci_bytes = cell["dci_link_bytes_per_device"]
    coll = (cell["link_bytes_per_device"] - dci_bytes) / ICI_BW
    dci = dci_bytes / DCI_BW
    terms = {"compute": comp, "memory": memt, "collective": coll + dci}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cell["arch"], cell["shape"])
    hlo_total = cell["flops_per_device"] * chips
    ratio = mf / hlo_total if hlo_total else 0.0
    bound = max(terms.values())
    useful_time = mf / (chips * PEAK)
    frac = useful_time / bound if bound else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "chips": chips,
        "compute_s": comp, "memory_s": memt, "collective_s": coll,
        "dci_s": dci, "dominant": dominant,
        "model_flops": mf, "hlo_flops": hlo_total, "useful_ratio": ratio,
        "roofline_fraction": frac,
        "arg_gib": cell["memory"]["argument_bytes"] / 2**30,
        "temp_gib": cell["memory"]["temp_bytes"] / 2**30,
    }


LEVERS = {
    "compute": "cut recompute (remat policy) / skip masked attention "
               "blocks (flash kernel)",
    "memory": "stop materializing fp32 logits — flash-attention kernel; "
              "tighter cache layout for windowed layers",
    "collective": "hoist FSDP all-gathers out of the microbatch loop / "
                  "hierarchical + compressed cross-pod exchange",
}


def render_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s "
           "| dci s | bound | MODEL/HLO | roofline frac | arg GiB/dev "
           "| temp GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} "
                 f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
                 f"| {r['dci_s']:.3g} | **{r['dominant']}** "
                 f"| {r['useful_ratio']:.2f} "
                 f"| {r['roofline_fraction']:.2%} | {r['arg_gib']:.2f} "
                 f"| {r['temp_gib']:.2f} |\n")
    return hdr + body


def skip_rows(result_dir: str = RESULT_DIR, mesh: str = "single"):
    out = []
    for f in sorted(glob.glob(os.path.join(result_dir, f"*__{mesh}.json"))):
        with open(f) as fh:
            c = json.load(fh)
        if c.get("status") == "SKIP":
            out.append((c["arch"], c["shape"], c.get("reason", "")))
    return out


def main() -> None:
    rows = [r for c in load_cells() if (r := roofline_row(c))]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print(render_table(rows))
    print("\nSKIPPED:")
    for arch, shape, reason in skip_rows():
        print(f"  {arch} {shape}: {reason}")


if __name__ == "__main__":
    main()
