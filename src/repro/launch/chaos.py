"""Chaos harness: train under an armed FaultPlan and survive it.

Runs the RecoverySupervisor with deterministic fault injection — op
delays / retried drops / payload bit-flips on every collective, plus
scheduled device loss (shrink remesh + checkpoint restore) and capacity
restore (grow remesh + live state redistribution):

    PYTHONPATH=src python -m repro.launch.chaos --arch h2o-danube-1.8b \
        --reduced --steps 10 --devices 8 --model-width 4 \
        --drop-rate 0.2 --delay-rate 0.2 --bitflip-rate 0.1 \
        --lose 5:4 --restore 8:8

The run's merged loss trajectory is printed step by step; with the same
seed and no ``--lose/--restore/--*-rate`` flags you get the fault-free
reference it must match (the chaos test automates exactly that
comparison).
"""
import argparse
import os


def _event(spec: str, kind: str):
    from repro.comms.faults import HostEvent
    step, n = spec.split(":")
    return HostEvent(int(step), kind, int(n))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale smoke)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--devices", type=int, default=8,
                    help="virtual device count (forced before jax import)")
    ap.add_argument("--model-width", type=int, default=4,
                    help="TP width every remesh preserves")
    ap.add_argument("--grad-comms", default="tree",
                    help="explicit transport so op faults hit the "
                         "gradient exchange ('auto' bypasses the "
                         "Communicator entirely)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--delay-rate", type=float, default=0.0)
    ap.add_argument("--drop-rate", type=float, default=0.0)
    ap.add_argument("--bitflip-rate", type=float, default=0.0)
    ap.add_argument("--lose", action="append", default=[],
                    metavar="STEP:NDEV",
                    help="kill devices before STEP, NDEV survive "
                         "(repeatable)")
    ap.add_argument("--restore", action="append", default=[],
                    metavar="STEP:NDEV",
                    help="restore capacity to NDEV before STEP "
                         "(repeatable)")
    ap.add_argument("--checkpoint-every", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/repro_chaos_ckpt")
    args = ap.parse_args()

    # the virtual device count must be pinned before jax initializes
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")

    from repro.comms import faults
    from repro.configs.base import SHAPES, ShapeSpec, get_config, reduced
    from repro.train.recovery import RecoveryConfig, RecoverySupervisor
    from repro.train.trainer import TrainerConfig

    cfg = get_config(args.arch)
    shape = SHAPES["train_4k"]
    if args.reduced:
        cfg = reduced(cfg)
        shape = ShapeSpec("reduced", "train", 128, 8)

    events = tuple(_event(s, faults.LOSE) for s in args.lose) + \
        tuple(_event(s, faults.RESTORE) for s in args.restore)
    plan = faults.FaultPlan(
        seed=args.seed, delay_rate=args.delay_rate,
        drop_rate=args.drop_rate, bitflip_rate=args.bitflip_rate,
        events=events)

    sup = RecoverySupervisor(
        cfg, shape,
        TrainerConfig(total_steps=args.steps,
                      checkpoint_every=args.checkpoint_every,
                      ckpt_dir=args.ckpt, grad_comms=args.grad_comms),
        RecoveryConfig(model_width=args.model_width))
    with faults.armed(plan):
        out = sup.run()

    print(f"[chaos] injected op faults: {len(faults.injection_log())}")
    print(f"[chaos] recoveries: {out['recoveries']} "
          f"(events: {out['events']})")
    if out["detect_to_resume_s"]:
        print("[chaos] detect-to-resume s: "
              + ", ".join(f"{t:.2f}" for t in out["detect_to_resume_s"]))
    print(f"[chaos] straggler flags: {out['flagged']}")
    for h in out["history"]:
        print(f"[chaos] step {h['step']} loss={h['loss']:.6f}")
    print(f"[chaos] final loss {out['final_loss']}")


if __name__ == "__main__":
    main()
