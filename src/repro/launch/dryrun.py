import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("REPRO_DRYRUN_DEVICES", "512")
    + " " + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh, with ShapeDtypeStruct inputs only
(no allocation), and record memory/cost/collective analysis for the
roofline.

The two lines above MUST run before any other import (jax locks the
device count at first init).  Run cells in subprocesses via ``--all``:

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
        --shape train_4k --mesh single --out out.json
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             local_mesh=None, reduced: bool = False,
             overrides: dict = None) -> dict:
    import dataclasses
    from repro.configs.base import SHAPES, get_config, input_specs, reduced as reduce_cfg
    from repro.launch.mesh import make_local_mesh, make_production_mesh
    from repro.models.model import Model
    from repro.optim.optimizer import OptimizerConfig, opt_init
    from repro.roofline import hlo as hlo_lib
    from repro.train import steps as steps_lib

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single"}
    if shape_name in cfg.skip_shapes:
        return {**meta, "status": "SKIP", "reason": cfg.skip_reason}
    if reduced:
        cfg = reduce_cfg(cfg)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
        meta["overrides"] = dict(overrides)
    if local_mesh:
        mesh = make_local_mesh(*local_mesh)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)

    model = Model(cfg, mesh)
    ocfg = OptimizerConfig(name=cfg.optimizer)
    bundle = steps_lib.sharding_bundle(model, ocfg, shape)
    ns = lambda s: NamedSharding(mesh, s)

    t0 = time.time()
    if shape.kind == "train":
        train_step, mb = steps_lib.make_train_step(
            model, ocfg, shape.global_batch)
        meta["microbatches"] = mb
        abstract_opt = bundle["abstract_opt"]
        step_s = jax.ShapeDtypeStruct((), jnp.int32)
        fn = jax.jit(
            train_step,
            in_shardings=(bundle["params"], bundle["opt"],
                          bundle["input_shardings"], ns(P())),
            out_shardings=(bundle["params"], bundle["opt"], None),
            donate_argnums=(0, 1))
        lowered = fn.lower(bundle["abstract_params"], abstract_opt,
                           bundle["inputs"], step_s)
    elif shape.kind == "prefill":
        prefill = steps_lib.make_prefill_step(model)
        inputs = dict(bundle["inputs"])
        tokens = inputs.pop("tokens")
        tok_sh = dict(bundle["input_shardings"])
        tok = tok_sh.pop("tokens")
        fn = jax.jit(prefill,
                     in_shardings=(bundle["params"], tok, tok_sh),
                     out_shardings=(None, bundle["cache"]))
        lowered = fn.lower(bundle["abstract_params"], tokens, inputs)
    else:  # decode
        decode = steps_lib.make_decode_step(model)
        inputs = bundle["inputs"]
        ish = bundle["input_shardings"]
        fn = jax.jit(decode,
                     in_shardings=(bundle["params"], ish["tokens"],
                                   ish["positions"], bundle["cache"]),
                     out_shardings=(None, bundle["cache"]),
                     donate_argnums=(3,))
        lowered = fn.lower(bundle["abstract_params"], inputs["tokens"],
                           inputs["positions"], bundle["abstract_cache"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    n_pods = mesh.shape.get("pod", 1)
    pod_size = mesh.devices.size // n_pods
    hlo = hlo_lib.analyze(compiled.as_text(), pod_size=pod_size,
                          n_pods=n_pods)
    n_dev = mesh.devices.size
    result = {
        **meta,
        "status": "OK",
        "devices": int(n_dev),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        # raw XLA numbers (while bodies counted once — undercounts loops)
        "xla_flops_per_device": cost.get("flops", 0.0),
        "xla_bytes_per_device": cost.get("bytes accessed", 0.0),
        # loop-adjusted HLO analysis (see repro.roofline.hlo)
        "flops_per_device": hlo["dot_flops"],
        "bytes_per_device": hlo["bytes"],
        "collectives": hlo["collectives"],
        "link_bytes_per_device": hlo["link_bytes"],
        "dci_link_bytes_per_device": hlo["dci_link_bytes"],
        "loops": hlo["loops"][:40],
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
    }
    print(f"[dryrun] {arch} {shape_name} mesh={meta['mesh']} "
          f"compile={t_compile:.1f}s flops/dev={result['flops_per_device']:.3e} "
          f"hbm/dev={hlo['bytes']/2**30:.2f}GiB "
          f"temp/dev={mem.temp_size_in_bytes/2**30:.2f}GiB "
          f"link/dev={hlo['link_bytes']/2**20:.1f}MiB "
          f"dci/dev={hlo['dci_link_bytes']/2**20:.1f}MiB")
    return result


def all_cells():
    from repro.configs.base import SHAPES, get_config, list_configs
    for arch in list_configs():
        for shape in SHAPES:
            yield arch, shape


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--local-mesh", type=str, default="",
                    help="data,model[,pod] sizes for small-scale testing")
    ap.add_argument("--out", type=str, default="")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--override", type=str, default="",
                    help="cfg overrides for perf A/B, e.g. "
                         "'microbatches=4,attn_logits_dtype=bf16'")
    args = ap.parse_args()
    overrides = {}
    for kv in filter(None, args.override.split(",")):
        k, v = kv.split("=")
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    os.makedirs(RESULT_DIR, exist_ok=True)
    local_mesh = None
    if args.local_mesh:
        parts = [int(x) for x in args.local_mesh.split(",")]
        local_mesh = tuple(parts)

    if args.all:
        # drive each cell in a subprocess (isolation + bounded memory)
        failures = []
        for arch, shape in all_cells():
            for mesh in (("single", "multi") if args.mesh == "both"
                         else (args.mesh,)):
                out = os.path.join(RESULT_DIR, f"{arch}__{shape}__{mesh}.json")
                if os.path.exists(out):
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh,
                       "--out", out]
                if args.reduced:
                    cmd.append("--reduced")
                r = subprocess.run(cmd, timeout=args.timeout)
                if r.returncode:
                    failures.append((arch, shape, mesh))
        print("FAILURES:", failures)
        return 1 if failures else 0

    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    rc = 0
    for mesh in meshes:
        try:
            res = run_cell(args.arch, args.shape, mesh == "multi",
                           local_mesh, args.reduced, overrides)
        except Exception as e:  # noqa: BLE001
            res = {"arch": args.arch, "shape": args.shape, "mesh": mesh,
                   "status": "FAIL", "error": traceback.format_exc()[-4000:]}
            print(f"[dryrun] FAIL {args.arch} {args.shape} {mesh}: {e}",
                  file=sys.stderr)
            rc = 1
        if args.out:
            with open(args.out, "w") as f:
                json.dump(res, f, indent=1)
    return rc


if __name__ == "__main__":
    sys.exit(main())
