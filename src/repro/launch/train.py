"""Training launcher.

Single-host (CPU/virtual devices) or multi-host (real cluster):

    PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
        --shape train_4k --steps 1000 --grad-comms hier --ckpt /ckpt/run1

Multi-host initialization is driven by the standard env variables
(COORDINATOR_ADDRESS, NUM_PROCESSES, PROCESS_ID) or Slurm via
``jax.distributed.initialize()`` auto-detection — see slurm_train.sbatch.
"""
import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    from repro.train.steps import GRAD_COMMS_MODES
    ap.add_argument("--grad-comms", default="auto",
                    choices=GRAD_COMMS_MODES,
                    help="'auto' = GSPMD; otherwise the transport a "
                         "CommSpec binds to the batch-axis Communicator; "
                         "'<transport>_overlap' double-buffers the "
                         "exchange behind the next microbatch's compute")
    ap.add_argument("--moe-comms", default="",
                    choices=("", "native", "tree", "serial", "hier",
                             "hier_int8"),
                    help="transport for the expert-parallel MoE "
                         "dispatch/combine all-to-all (default: the "
                         "arch config's moe_comms, usually 'native')")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale smoke)")
    ap.add_argument("--mesh", default="",
                    help="'data,model[,pod]' (default: production mesh "
                         "when enough devices, else auto-factored)")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (multi-host)")
    args = ap.parse_args()

    if args.distributed:
        import jax
        jax.distributed.initialize()

    import jax
    from repro.configs.base import SHAPES, ShapeSpec, get_config, reduced
    from repro.launch.mesh import (make_local_mesh, make_production_mesh,
                                   mesh_for_devices)
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.reduced:
        cfg = reduced(cfg)
        shape = ShapeSpec("reduced", "train", 128, 8)
    if args.moe_comms:
        import dataclasses
        cfg = dataclasses.replace(cfg, moe_comms=args.moe_comms)

    n = len(jax.devices())
    if args.mesh:
        parts = [int(x) for x in args.mesh.split(",")]
        mesh = make_local_mesh(*parts)
    elif n >= 512:
        mesh = make_production_mesh(multi_pod=True)
    elif n >= 256:
        mesh = make_production_mesh()
    else:
        mesh = mesh_for_devices(n)
    print(f"[launch] devices={n} mesh={dict(mesh.shape)}")

    trainer = Trainer(cfg, shape, mesh, TrainerConfig(
        total_steps=args.steps, checkpoint_every=args.checkpoint_every,
        ckpt_dir=args.ckpt, grad_comms=args.grad_comms))
    out = trainer.run(resume=True)
    print(f"[launch] done; final loss {out['final_loss']}")


if __name__ == "__main__":
    main()
