"""Mesh construction.  ``make_production_mesh`` is a function (never a
module-level constant) so importing this module touches no jax device
state — required because the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before jax init.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: 16x16 chips per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (virtual) devices exist — tests and
    CPU examples."""
    n = len(jax.devices())
    need = max(1, data) * max(1, model) * max(1, pod or 1)
    assert need <= n, f"need {need} devices, have {n}"
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_for_devices(n: int, prefer_model: int = 0):
    """Factor ``n`` devices into a (data, model) mesh."""
    model = prefer_model or int(np.gcd(n, 16))
    while n % model:
        model //= 2
    return jax.make_mesh((n // model, model), ("data", "model"))
