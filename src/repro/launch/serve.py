"""Serving launcher: load (or randomly init) a model and serve a batch of
synthetic requests through the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --reduced
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs.base import get_config, reduced
    from repro.launch.mesh import mesh_for_devices
    from repro.models.model import Model
    from repro.serve.engine import Engine, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = mesh_for_devices(len(jax.devices()))
    engine = Engine(cfg, mesh, slots=args.slots, max_len=args.max_len)
    model = Model(cfg, mesh)
    if args.ckpt:
        from repro.checkpoint import checkpoint as ck
        step = ck.latest_step(args.ckpt)
        tree = ck.restore(args.ckpt, step,
                          {"params": model.init_abstract()})
        params = tree["params"]
    else:
        params = model.init(jax.random.PRNGKey(0))
    engine.load(params)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(8, 64))),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    results = engine.run_to_completion(reqs)
    done = sum(len(v) for v in results.values())
    print(f"[serve] completed {len(results)}/{args.requests} requests, "
          f"{done} tokens")


if __name__ == "__main__":
    main()
