"""Serving launcher: load (or randomly init) a model and serve a batch of
synthetic requests through the engine, reporting tokens/sec and p95 TTFT.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --reduced
"""
import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--cache-mode", default="auto",
                    choices=["auto", "paged", "dense", "legacy"],
                    help="paged = block-pool KV cache (default on "
                         "attention-only archs)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block (paged mode)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="pool size in blocks (0 = dense-equivalent)")
    ap.add_argument("--policy", default="conservative",
                    choices=["conservative", "mixed"],
                    help="tick policy: conservative keeps greedy decode "
                         "bit-stable; mixed packs decode into prefill "
                         "dispatches")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="mean request arrivals/sec (0 = all at once)")
    ap.add_argument("--max-steps", type=int, default=10_000)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs.base import get_config, reduced
    from repro.launch.mesh import mesh_for_devices
    from repro.models.model import Model
    from repro.serve import Engine, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    mesh = mesh_for_devices(len(jax.devices()))
    engine = Engine(cfg, mesh, slots=args.slots, max_len=args.max_len,
                    cache_mode=args.cache_mode,
                    block_size=args.block_size,
                    num_blocks=args.num_blocks or None,
                    policy=args.policy)
    model = Model(cfg, mesh)
    if args.ckpt:
        from repro.checkpoint import checkpoint as ck
        step = ck.latest_step(args.ckpt)
        tree = ck.restore(args.ckpt, step,
                          {"params": model.init_abstract()})
        params = tree["params"]
    else:
        params = model.init(jax.random.PRNGKey(0))
    engine.load(params)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=int(rng.integers(8, 64))),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    if args.rate > 0:
        gaps = rng.exponential(1.0 / args.rate, size=args.requests)
        arrivals = [float(t) for t in np.cumsum(gaps)]
    else:
        arrivals = [0.0] * args.requests
    results = engine.run_trace(reqs, arrivals, max_steps=args.max_steps)

    done_tokens = sum(len(v) for v in results.values())
    ttfts = sorted(m["ttft_s"] for m in results.metrics.values()
                   if m.get("ttft_s") is not None)
    elapsed = max((m.get("done_s", 0.0)
                   for m in results.metrics.values()), default=0.0)
    print(f"[serve] mode={engine.cache_mode} completed "
          f"{len(results)}/{args.requests} requests, {done_tokens} tokens")
    if ttfts and elapsed > 0:
        p95 = ttfts[min(len(ttfts) - 1, int(0.95 * len(ttfts)))]
        print(f"[serve] {done_tokens / elapsed:.0f} tok/s, "
              f"p95 TTFT {p95 * 1e3:.1f} ms")
    if engine.pool is not None:
        print(f"[serve] pool high water {engine.pool.high_water}/"
              f"{engine.pool.num_blocks} blocks "
              f"({engine.pool.block_size} tokens each)")
    if results.truncated:
        unfinished = sorted(results.unfinished)
        raise SystemExit(
            f"[serve] TRUNCATED at --max-steps={args.max_steps}: "
            f"unfinished requests {unfinished}")


if __name__ == "__main__":
    main()
