"""Composable blocks: self-attention (+dense/MoE FFN), cross-attention,
encoder, and the Hymba parallel attention+SSM block.

Every ``apply_*`` runs in one of three modes:
  * ``train``  — no cache, full-sequence causal attention;
  * ``chunk``  — chunked prefill: attend over [cache ++ chunk], then write
                 the chunk into the ring;
  * ``decode`` — single token: write first, attend over the ring only.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GLOBAL_WINDOW
from repro.models import cache as cache_lib
from repro.models.layers import (attention, dense_init, rmsnorm,
                                 rmsnorm_init, rope, swiglu, swiglu_init)
from repro.models.moe import moe_ffn, moe_init
from repro.models.ssm import ssm_forward, ssm_init

Array = jax.Array


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def attn_init(key, d_model, heads, kv_heads, dh, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    return {
        "norm": rmsnorm_init(d_model, dtype),
        "wq": dense_init(ks[0], (d_model, heads * dh), dtype),
        "wk": dense_init(ks[1], (d_model, kv_heads * dh), dtype),
        "wv": dense_init(ks[2], (d_model, kv_heads * dh), dtype),
        "wo": dense_init(ks[3], (heads * dh, d_model), dtype),
    }


def ffn_init(key, d_model, d_ff, kind, num_experts=0, dtype=jnp.bfloat16):
    p = {"fnorm": rmsnorm_init(d_model, dtype)}
    if kind == "dense":
        p["ffn"] = swiglu_init(key, d_model, d_ff, dtype)
    elif kind == "moe":
        k1, k2 = jax.random.split(key)
        p["moe"] = moe_init(k1, d_model, d_ff, num_experts, dtype)
    return p


def xattn_init(key, d_model, heads, kv_heads, dh, gated, dtype=jnp.bfloat16):
    p = attn_init(key, d_model, heads, kv_heads, dh, dtype)
    if gated:
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_ffn"] = jnp.zeros((), jnp.float32)
    return p


def hymba_init(key, d_model, heads, kv_heads, dh, d_inner, state,
               dtype=jnp.bfloat16):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = attn_init(k1, d_model, heads, kv_heads, dh, dtype)
    p["ssm"] = ssm_init(k2, d_model, d_inner, state, dtype)
    p["anorm"] = rmsnorm_init(d_model, dtype)
    p["snorm"] = rmsnorm_init(d_model, dtype)
    return p


# ---------------------------------------------------------------------------
# attention core shared by self/cross blocks
# ---------------------------------------------------------------------------

def _qkv(p, xq: Array, xkv: Array, heads, kv_heads, dh):
    B, C, _ = xq.shape
    N = xkv.shape[1]
    q = (xq @ p["wq"]).reshape(B, C, heads, dh)
    k = (xkv @ p["wk"]).reshape(B, N, kv_heads, dh)
    v = (xkv @ p["wv"]).reshape(B, N, kv_heads, dh)
    return q, k, v


def self_attention(p, x, pos, kv, *, heads, kv_heads, dh, window, theta,
                   mode, q_chunk, logits_dtype=jnp.float32
                   ) -> Tuple[Array, Optional[dict]]:
    """x: (B, C, D); pos: (B, C); kv: {'k','v'} (B,W,...) + group-level pos
    handled by the caller (passed as kv['pos']).  A paged entry carries a
    block table in kv['btab'] and its k/v are the shared physical pool
    (num_blocks, bs, H, dh) instead of per-slot rings — same update
    discipline, reads/writes go through the table."""
    xn = rmsnorm(p["norm"], x)
    q, k, v = _qkv(p, xn, xn, heads, kv_heads, dh)
    q = rope(q, pos, theta)
    k = rope(k, pos, theta)
    new_kv = None
    paged = kv is not None and "btab" in kv
    if mode == "train":
        out = attention(q, k, v, pos, pos, window=window, causal=True,
                        q_chunk=q_chunk, logits_dtype=logits_dtype)
    elif mode == "chunk":
        old_k = cache_lib.paged_gather(kv["k"], kv["btab"]) if paged \
            else kv["k"]
        old_v = cache_lib.paged_gather(kv["v"], kv["btab"]) if paged \
            else kv["v"]
        keys = jnp.concatenate([old_k, k], axis=1)
        vals = jnp.concatenate([old_v, v], axis=1)
        k_pos = jnp.concatenate([kv["pos"], pos], axis=1)
        out = attention(q, keys, vals, pos, k_pos, window=window,
                        causal=True, q_chunk=q_chunk,
                        logits_dtype=logits_dtype)
        if paged:
            k2 = cache_lib.paged_scatter(kv["k"], kv["btab"], k, pos)
            v2 = cache_lib.paged_scatter(kv["v"], kv["btab"], v, pos)
        else:
            k2, v2, _ = cache_lib.update_kv(kv["k"], kv["v"], kv["pos"],
                                            k, v, pos)
        new_kv = {"k": k2, "v": v2}
    else:  # decode: update-then-attend
        pos2 = cache_lib.scatter_ring(kv["pos"], pos, pos)
        if paged:
            k2 = cache_lib.paged_scatter(kv["k"], kv["btab"], k, pos)
            v2 = cache_lib.paged_scatter(kv["v"], kv["btab"], v, pos)
            gk = cache_lib.paged_gather(k2, kv["btab"])
            gv = cache_lib.paged_gather(v2, kv["btab"])
        else:
            k2 = cache_lib.scatter_ring(kv["k"], k, pos)
            v2 = cache_lib.scatter_ring(kv["v"], v, pos)
            gk, gv = k2, v2
        out = attention(q, gk, gv, pos, pos2, window=window, causal=True)
        new_kv = {"k": k2, "v": v2}
    B, C = x.shape[:2]
    return out.reshape(B, C, heads * dh) @ p["wo"], new_kv


def cross_attention(p, x, media_kv, *, heads, kv_heads, dh
                    ) -> Array:
    """media_kv: {'k','v'} (B, N, kv_heads, dh) precomputed/cached."""
    B, C, _ = x.shape
    xn = rmsnorm(p["norm"], x)
    q = (xn @ p["wq"]).reshape(B, C, heads, dh)
    N = media_kv["k"].shape[1]
    zeros = jnp.zeros((B, N), jnp.int32)
    qp = jnp.zeros((B, C), jnp.int32)
    out = attention(q, media_kv["k"], media_kv["v"], qp, zeros,
                    causal=False)
    return out.reshape(B, C, heads * dh) @ p["wo"]


def media_kv_of(p, media: Array, kv_heads, dh) -> Dict[str, Array]:
    B, N, _ = media.shape
    return {"k": (media @ p["wk"]).reshape(B, N, kv_heads, dh),
            "v": (media @ p["wv"]).reshape(B, N, kv_heads, dh)}


# ---------------------------------------------------------------------------
# FFN application
# ---------------------------------------------------------------------------

def apply_ffn(p, x, *, kind, moe_kwargs, mode) -> Tuple[Array, Array]:
    if kind == "none":
        return x, jnp.zeros((), jnp.float32)
    xn = rmsnorm(p["fnorm"], x)
    if kind == "dense":
        return x + swiglu(p["ffn"], xn), jnp.zeros((), jnp.float32)
    moe_mode = "replicated" if mode == "decode" else "scatter"
    y, aux = moe_ffn(p["moe"], xn, mode=moe_mode, **moe_kwargs)
    return x + y, aux
