"""Partition rules: every parameter / cache / input leaf -> PartitionSpec.

Strategy (see DESIGN.md §8):
  * tensor parallelism on ``model`` (attention head/feature dims, FFN
    width, vocab, experts);
  * optional FSDP over ``data`` (+``pod`` for the >=400B MoEs) on the
    other weight dim;
  * batch over (``pod``, ``data``);
  * decode KV caches: sequence dim over ``model`` (uniform rule — keeps
    kv_heads < mesh-width archs shardable); batch==1 long-context shards
    the sequence over (``data``, ``model``).

``fit_spec`` drops any mesh axis that does not divide the corresponding
dim, so one rule set serves full-size configs, reduced smoke configs and
any mesh shape.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def mesh_batch_axes(mesh: Mesh, cfg: ArchConfig = None) -> Tuple[str, ...]:
    """Axes the batch shards over.  Under the 'replicate' strategy the
    model axis holds no weight shards, so the batch claims it too (pure
    DP over the whole mesh)."""
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if cfg is not None and cfg.shard_strategy == "replicate" \
            and "model" in mesh.axis_names:
        axes = axes + ("model",)
    return axes


def fsdp_axes_for(cfg: ArchConfig, mesh: Mesh) -> Tuple[str, ...]:
    if not cfg.use_fsdp or "data" not in mesh.axis_names:
        return ()
    axes = ["data"]
    if cfg.use_pod_fsdp and "pod" in mesh.axis_names:
        axes.append("pod")
    return tuple(axes)


def expert_fsdp_axes(cfg: ArchConfig, mesh: Mesh) -> Tuple[str, ...]:
    """FSDP axes that divide the expert FFN width (must match moe_ffn)."""
    kept = []
    f = cfg.d_ff
    for a in fsdp_axes_for(cfg, mesh):
        sz = mesh.shape[a]
        if f % sz == 0:
            kept.append(a)
            f //= sz
    return tuple(kept)


def fit_spec(shape: Tuple[int, ...], spec: P, mesh: Mesh) -> P:
    """Drop axis names that do not divide the dim they shard."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        kept = []
        rem = dim
        for n in names:
            sz = mesh.shape[n]
            if rem % sz == 0:
                kept.append(n)
                rem //= sz
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

_COL = {"wq", "wk", "wv", "wg", "w1", "w3", "win", "wi", "wf",
        "wz", "wo_gates", "conv"}          # (.., D, X): shard X on model
_ROW = {"wo", "w2", "wout", "wo_out"}      # (.., X, D): shard X on model


def _param_rule(path: Tuple[str, ...], shape, cfg: ArchConfig, mesh: Mesh,
                fsdp, efsdp) -> P:
    name = None
    for p in reversed(path):
        if isinstance(p, str):
            name = p
            break
    nd = len(shape)
    pad = (None,) * max(0, nd - 2)
    f = fsdp if fsdp else None
    ef = efsdp if efsdp else None
    if name == "emb":
        return fit_spec(shape, P("model", f), mesh)
    if name == "unemb":
        return fit_spec(shape, P(f, "model"), mesh)
    if name in ("we1", "we3"):
        return fit_spec(shape, P(*pad[:-1], "model", None, ef), mesh)
    if name == "we2":
        return fit_spec(shape, P(*pad[:-1], "model", ef, None), mesh)
    if name == "wr":
        return P()
    if name in ("scale", "dskip", "alog", "gate_attn", "gate_ffn"):
        return P()
    if name in ("rz", "ri", "rf", "ro"):
        return fit_spec(shape, P(*pad, None, "model"), mesh) if nd >= 2 else P()
    if name in ("wdt", "wbc"):
        return fit_spec(shape, P(*pad, f, "model"), mesh)
    if name in _ROW:
        return fit_spec(shape, P(*pad, "model", f), mesh)
    if name in _COL or (nd >= 2 and name and name.startswith("w")):
        return fit_spec(shape, P(*pad, f, "model"), mesh)
    return P()


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(k.key)
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(k.name)
    return tuple(out)


def param_pspecs(cfg: ArchConfig, abstract_params, mesh: Mesh):
    fsdp = fsdp_axes_for(cfg, mesh)
    efsdp = expert_fsdp_axes(cfg, mesh)
    keep_model = {"emb", "unemb"}

    def rule(path, leaf):
        names = _path_names(path)
        spec = _param_rule(names, leaf.shape, cfg, mesh, fsdp, efsdp)
        if cfg.shard_strategy == "replicate" and \
                not (names and names[-1] in keep_model):
            spec = _strip_model(spec)
        return spec

    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def _strip_model(spec: P) -> P:
    out = []
    for e in tuple(spec):
        if e is None:
            out.append(None)
        else:
            names = tuple(n for n in (e if isinstance(e, tuple) else (e,))
                          if n != "model")
            out.append(names if len(names) > 1
                       else (names[0] if names else None))
    return P(*out)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def cache_pspecs(cfg: ArchConfig, cache_specs, mesh: Mesh, batch: int):
    baxes = mesh_batch_axes(mesh, cfg)
    bprod = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    b_ok = baxes and batch % bprod == 0
    batch_entry = baxes if b_ok else None
    if b_ok:
        seq_entry = None if "model" in baxes else "model"
    else:
        seq_entry = tuple(dict.fromkeys(list(baxes) + ["model"]))

    def rule(path, leaf):
        names = _path_names(path)
        key = names[-1]
        nd = len(leaf.shape)
        if key in ("k", "v"):
            if nd == 5:
                return fit_spec(leaf.shape,
                                P(None, batch_entry, seq_entry, None, None),
                                mesh)
            return P()
        if key == "pos":
            return fit_spec(leaf.shape, P(batch_entry, seq_entry), mesh)
        if key == "C":       # mlstm (count,B,H,dh,dh)
            return fit_spec(leaf.shape,
                            P(None, batch_entry, None, "model", None), mesh)
        if key in ("n", "c", "h2", "m"):
            return fit_spec(leaf.shape,
                            P(None, batch_entry, None, "model"), mesh)
        if key == "h":
            if nd == 4 and leaf.shape[-1] == cfg.ssm_state:
                # hymba ssm state (count,B,d_inner,state)
                return fit_spec(leaf.shape,
                                P(None, batch_entry, "model", None), mesh)
            return fit_spec(leaf.shape,
                            P(None, batch_entry, None, "model"), mesh)
        if key == "conv":
            return fit_spec(leaf.shape,
                            P(None, batch_entry, None, "model"), mesh)
        return P()

    return jax.tree_util.tree_map_with_path(rule, cache_specs)


# ---------------------------------------------------------------------------
# inputs
# ---------------------------------------------------------------------------

def input_pspecs(cfg: ArchConfig, specs: Dict[str, Any], mesh: Mesh):
    baxes = mesh_batch_axes(mesh, cfg)
    b = baxes if baxes else None

    out = {}
    for k, v in specs.items():
        spec = P(b, *([None] * (len(v.shape) - 1)))
        out[k] = fit_spec(v.shape, spec, mesh)
    return out


def shardings_of(pspecs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
