"""Recurrent blocks: xLSTM (mLSTM + sLSTM) and a Mamba-style selective SSM
(the Hymba parallel branch).

mLSTM uses the standard *chunkwise-parallel* formulation (intra-chunk
attention-like term + inter-chunk state carry, log-space stabilised), so
train/prefill is O(S * L_chunk) matmul work instead of a length-S scan.
The strictly-sequential scan form lives in ``mlstm_sequential`` and is
the test oracle.  sLSTM has no parallel form (that is its point — xLSTM
paper §2.3); it is a `lax.scan` over time.

The selective SSM uses a chunked associative scan (log-depth within a
chunk, state carried across chunks) which is both compile-compact and
TPU-friendly.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, rmsnorm, rmsnorm_init

Array = jax.Array


# ===========================================================================
# mLSTM
# ===========================================================================

def mlstm_init(key: Array, d_model: int, heads: int, dh: int,
               dtype=jnp.bfloat16) -> Dict[str, Array]:
    ks = jax.random.split(key, 7)
    q_dim = heads * dh
    return {
        "norm": rmsnorm_init(d_model, dtype),
        "wq": dense_init(ks[0], (d_model, q_dim), dtype),
        "wk": dense_init(ks[1], (d_model, q_dim), dtype),
        "wv": dense_init(ks[2], (d_model, q_dim), dtype),
        "wi": dense_init(ks[3], (d_model, heads), jnp.float32),
        "wf": dense_init(ks[4], (d_model, heads), jnp.float32),
        "wg": dense_init(ks[5], (d_model, q_dim), dtype),
        "wo": dense_init(ks[6], (q_dim, d_model), dtype),
        "onorm": rmsnorm_init(q_dim, dtype),
    }


def _mlstm_chunk(q, k, v, li, lf, state):
    """One chunk of the chunkwise-parallel mLSTM.

    q/k/v: (B, H, L, dh) f32; li/lf: (B, H, L) log input gate preact /
    log-sigmoid forget gate; state: (C (B,H,dh,dh), n (B,H,dh), m (B,H)).
    Returns (h (B,H,L,dh), new state).
    """
    C_in, n_in, m_in = state
    B, H, L, dh = q.shape
    b = jnp.cumsum(lf, axis=-1)                          # (B,H,L) inclusive
    # intra-chunk log scores: g[t,s] = b_t - b_s + li_s  for s <= t
    g = b[..., :, None] - b[..., None, :] + li[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    g = jnp.where(tri, g, -jnp.inf)
    m_intra = jnp.max(g, axis=-1)                        # (B,H,L)
    m_t = jnp.maximum(m_in[..., None] + b, m_intra)      # (B,H,L)
    # stabilised intra scores
    s = jnp.exp(g - m_t[..., None])                      # (B,H,L,L)
    scale = dh ** -0.5
    qk = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale
    w = qk * s
    inter_coef = jnp.exp(m_in[..., None] + b - m_t)      # (B,H,L)
    num = jnp.einsum("bhts,bhsd->bhtd", w, v) \
        + jnp.einsum("bhtd,bhde->bhte", q * inter_coef[..., None] * scale, C_in)
    den = jnp.sum(w, axis=-1) \
        + jnp.einsum("bhtd,bhd->bht", q * inter_coef[..., None] * scale, n_in)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
    # state update
    bL = b[..., -1]                                      # (B,H)
    dec = bL[..., None] - b + li                         # (B,H,L)
    m_out = jnp.maximum(m_in + bL, jnp.max(dec, axis=-1))
    carry = jnp.exp(m_in + bL - m_out)
    kv_coef = jnp.exp(dec - m_out[..., None])            # (B,H,L)
    C_out = C_in * carry[..., None, None] \
        + jnp.einsum("bhs,bhsd,bhse->bhde", kv_coef, k, v)
    n_out = n_in * carry[..., None] + jnp.einsum("bhs,bhsd->bhd", kv_coef, k)
    return h, (C_out, n_out, m_out)


def mlstm_forward(params: Dict[str, Array], x: Array, state, *,
                  heads: int, dh: int, chunk: int = 256,
                  compute_dtype=jnp.float32) -> Tuple[Array, tuple]:
    """Full mLSTM block.  x: (B, S, D); state: (C, n, m) or None (=> zeros).

    Returns (residual output (B, S, D), new state).  ``compute_dtype``
    controls the intra-chunk q/k/v buffers (bf16 halves their HBM
    traffic; the gate/decay math stays fp32)."""
    B, S, D = x.shape
    xn = rmsnorm(params["norm"], x)
    q = (xn @ params["wq"]).reshape(B, S, heads, dh).transpose(0, 2, 1, 3)
    k = (xn @ params["wk"]).reshape(B, S, heads, dh).transpose(0, 2, 1, 3)
    v = (xn @ params["wv"]).reshape(B, S, heads, dh).transpose(0, 2, 1, 3)
    q = q.astype(compute_dtype)
    k = k.astype(compute_dtype)
    v = v.astype(compute_dtype)
    li = (xn.astype(jnp.float32) @ params["wi"]).transpose(0, 2, 1)  # (B,H,S)
    lf = jax.nn.log_sigmoid(
        (xn.astype(jnp.float32) @ params["wf"]).transpose(0, 2, 1))
    if state is None:
        state = (jnp.zeros((B, heads, dh, dh), jnp.float32),
                 jnp.zeros((B, heads, dh), jnp.float32),
                 jnp.full((B, heads), -jnp.inf, jnp.float32))
    L = min(chunk, S)
    if S % L:
        L = S
    n = S // L

    def step(st, xs):
        qc, kc, vc, lic, lfc = xs
        h, st2 = _mlstm_chunk(qc, kc, vc, lic, lfc, st)
        return st2, h

    xs = tuple(jnp.moveaxis(a.reshape(B, heads, n, L, -1).squeeze(-1)
                            if a.ndim == 3 else a.reshape(B, heads, n, L, dh),
                            2, 0)
               for a in (q, k, v, li, lf))
    state, hs = lax.scan(step, state, xs)
    h = jnp.moveaxis(hs, 0, 2).reshape(B, heads, S, dh)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, heads * dh).astype(x.dtype)
    h = rmsnorm(params["onorm"], h)
    gate = jax.nn.sigmoid((xn @ params["wg"]).astype(jnp.float32))
    y = (h.astype(jnp.float32) * gate).astype(x.dtype) @ params["wo"]
    return x + y, state


def mlstm_sequential(params, x, state, *, heads, dh):
    """Step-by-step oracle for tests (identical math, L=1 chunks)."""
    return mlstm_forward(params, x, state, heads=heads, dh=dh, chunk=1)


# ===========================================================================
# sLSTM
# ===========================================================================

def slstm_init(key: Array, d_model: int, heads: int, dh: int,
               dtype=jnp.bfloat16) -> Dict[str, Array]:
    ks = jax.random.split(key, 10)
    q_dim = heads * dh
    p = {"norm": rmsnorm_init(d_model, dtype)}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w{g}"] = dense_init(ks[i], (d_model, q_dim), jnp.float32)
        p[f"r{g}"] = dense_init(ks[4 + i], (heads, dh, dh), jnp.float32,
                                scale=dh ** -0.5)
    p["wo_out"] = dense_init(ks[8], (q_dim, d_model), dtype)
    p["onorm"] = rmsnorm_init(q_dim, dtype)
    return p


def slstm_forward(params: Dict[str, Array], x: Array, state, *,
                  heads: int, dh: int, compute_dtype=jnp.float32
                  ) -> Tuple[Array, tuple]:
    """sLSTM block — strictly sequential exponential-gated LSTM with
    per-head recurrent mixing.  x: (B, S, D).  ``compute_dtype=bf16``
    halves the per-timestep recurrent-weight reads (gate math stays
    fp32)."""
    B, S, D = x.shape
    xn = rmsnorm(params["norm"], x).astype(jnp.float32)
    pre = {g: (xn @ params[f"w{g}"]).reshape(B, S, heads, dh)
           for g in ("z", "i", "f", "o")}
    rec_w = {g: params[f"r{g}"].astype(compute_dtype)
             for g in ("z", "i", "f", "o")}
    if state is None:
        state = (jnp.zeros((B, heads, dh), jnp.float32),
                 jnp.zeros((B, heads, dh), jnp.float32),
                 jnp.zeros((B, heads, dh), jnp.float32),
                 jnp.full((B, heads, dh), -jnp.inf, jnp.float32))

    def step(st, xs):
        c, n, h, m = st
        zx, ix, fx, ox = xs                              # each (B, H, dh)
        hc = h.astype(compute_dtype)
        rec = {g: jnp.einsum("bhd,hde->bhe", hc, rec_w[g]
                             ).astype(jnp.float32)
               for g in ("z", "i", "f", "o")}
        z = jnp.tanh(zx + rec["z"])
        li = ix + rec["i"]                                # log input gate
        lf = jax.nn.log_sigmoid(fx + rec["f"])            # log forget gate
        o = jax.nn.sigmoid(ox + rec["o"])
        m2 = jnp.maximum(lf + m, li)
        ig = jnp.exp(li - m2)
        fg = jnp.exp(lf + m - m2)
        c2 = fg * c + ig * z
        n2 = fg * n + ig
        h2 = o * c2 / jnp.maximum(n2, 1e-6)
        return (c2, n2, h2, m2), h2

    xs = tuple(jnp.moveaxis(pre[g], 1, 0) for g in ("z", "i", "f", "o"))
    state, hs = lax.scan(step, state, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, heads * dh).astype(x.dtype)
    h = rmsnorm(params["onorm"], h)
    return x + h @ params["wo_out"], state


# ===========================================================================
# Selective SSM (Hymba's Mamba-style branch)
# ===========================================================================

def ssm_init(key: Array, d_model: int, d_inner: int, state: int,
             dtype=jnp.bfloat16) -> Dict[str, Array]:
    ks = jax.random.split(key, 6)
    return {
        "win": dense_init(ks[0], (d_model, 2 * d_inner), dtype),
        "conv": dense_init(ks[1], (4, d_inner), jnp.float32, scale=0.5),
        "wdt": dense_init(ks[2], (d_inner, d_inner), jnp.float32,
                          scale=d_inner ** -0.5),
        "wbc": dense_init(ks[3], (d_inner, 2 * state), jnp.float32),
        "alog": jnp.log(jnp.arange(1, state + 1, dtype=jnp.float32)
                        )[None, :].repeat(d_inner, 0),      # (d_inner, state)
        "dskip": jnp.ones((d_inner,), jnp.float32),
        "wout": dense_init(ks[4], (d_inner, d_model), dtype),
    }


def _ssm_scan_chunked(a: Array, b: Array, h0: Array, chunk: int):
    """Linear recurrence h_t = a_t * h_{t-1} + b_t via chunked associative
    scan.  a/b: (B, S, d, state) f32; h0: (B, d, state)."""
    B, S, d, st = a.shape
    L = min(chunk, S)
    if S % L:
        L = S
    n = S // L
    ar = jnp.moveaxis(a.reshape(B, n, L, d, st), 1, 0)
    br = jnp.moveaxis(b.reshape(B, n, L, d, st), 1, 0)

    def combine(x, y):
        (ax, bx), (ay, by) = x, y
        return ax * ay, ay * bx + by

    def step(h, xs):
        ac, bc = xs
        aa, bb = lax.associative_scan(combine, (ac, bc), axis=1)
        hs = aa * h[:, None] + bb                        # (B, L, d, state)
        return hs[:, -1], hs

    hN, hs = lax.scan(step, h0, (ar, br))
    return jnp.moveaxis(hs, 0, 1).reshape(B, S, d, st), hN


def ssm_forward(params: Dict[str, Array], xn: Array, state_cache, *,
                d_inner: int, state: int, chunk: int = 512,
                scan_dtype=jnp.float32) -> Tuple[Array, tuple]:
    """Selective-SSM branch.  xn: (B, S, D) already normalised.
    state_cache: (h (B,d_inner,state), conv (B,3,d_inner)) or None.
    Returns (branch output (B, S, D), new state_cache)."""
    B, S, D = xn.shape
    xi, z = jnp.split(xn @ params["win"], 2, axis=-1)
    xi32 = xi.astype(jnp.float32)
    if state_cache is None:
        h0 = jnp.zeros((B, d_inner, state), jnp.float32)
        conv_in = jnp.zeros((B, 3, d_inner), jnp.float32)
    else:
        h0, conv_in = state_cache[0], state_cache[1]
    xc = jnp.concatenate([conv_in, xi32], axis=1)        # (B, S+3, d)
    taps = params["conv"]                                # (4, d)
    xconv = sum(xc[:, i:i + S] * taps[i] for i in range(4))
    xconv = jax.nn.silu(xconv)                           # (B, S, d)
    new_conv = xc[:, -3:]

    dt = jax.nn.softplus(xconv @ params["wdt"])          # (B, S, d)
    bc = xconv @ params["wbc"]
    Bm, Cm = jnp.split(bc, 2, axis=-1)                   # (B, S, state)
    A = -jnp.exp(params["alog"])                         # (d, state)
    a = jnp.exp(dt[..., None] * A).astype(scan_dtype)    # (B,S,d,state)
    bterm = ((dt * xconv)[..., None]
             * Bm[:, :, None, :]).astype(scan_dtype)
    hs, hN = _ssm_scan_chunked(a, bterm, h0.astype(scan_dtype), chunk)
    hN = hN.astype(jnp.float32)
    y = jnp.einsum("bsdn,bsn->bsd", hs.astype(jnp.float32), Cm) \
        + params["dskip"] * xconv
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(xn.dtype) @ params["wout"]
    return out, (hN, new_conv)
