"""Unified model: every assigned architecture is a sequence of *groups*,
each group a `lax.scan` over ``count`` structurally-identical superblocks
(1..6 sub-blocks each).  Heterogeneous layer patterns (gemma's 5 local :
1 global, llama-vision's 4 self : 1 cross, llama4's dense/MoE alternation,
xLSTM's mLSTM/sLSTM interleave) become superblock structure, so the HLO
stays O(1) in depth — essential for the 512-device dry-run sweep.

Public surface:
    Model(cfg, mesh)   .init  .train_loss  .prefill  .decode_step
                       .serve_step  .reset_cache_slots
                       .cache_specs  .param_specs (see partition.py)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh

from repro.configs.base import (ATTN, GLOBAL_WINDOW, HYMBA, MLSTM, SLSTM,
                                XATTN, ArchConfig)
from repro.models import blocks, cache as cache_lib
from repro.models.layers import (dense_init, rmsnorm, rmsnorm_init,
                                 softmax_xent_chunked, logits_for)
from repro.models.ssm import (mlstm_forward, mlstm_init, slstm_forward,
                              slstm_init, ssm_forward)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SubBlockDef:
    kind: str                     # attn | xattn | mlstm | slstm | hymba | enc
    window: int = GLOBAL_WINDOW
    theta: float = 10_000.0
    ffn: str = "dense"            # dense | moe | none
    d_ff: int = 0
    gated: bool = False           # tanh-gated cross-attn (llama-vision)
    use_window_array: bool = False


@dataclasses.dataclass(frozen=True)
class GroupDef:
    name: str
    count: int
    subs: Tuple[SubBlockDef, ...]
    window_array: Tuple[int, ...] = ()   # per-superblock window (hymba)


def build_groups(cfg: ArchConfig) -> Tuple[List[GroupDef], List[GroupDef]]:
    """Returns (decoder groups, encoder groups)."""
    enc: List[GroupDef] = []
    if cfg.encoder_layers:
        enc.append(GroupDef("enc", cfg.encoder_layers,
                            (SubBlockDef("enc", d_ff=cfg.d_ff),)))

    dec: List[GroupDef] = []
    w = cfg.sliding_window or GLOBAL_WINDOW
    if cfg.xlstm_pattern:
        pat = tuple(SubBlockDef(k, ffn="none") for k in cfg.xlstm_pattern)
        dec.append(GroupDef("xlstm", cfg.num_layers // len(pat), pat))
    elif cfg.family == "hybrid":
        dec.append(GroupDef(
            "hymba", cfg.num_layers,
            (SubBlockDef(HYMBA, d_ff=cfg.d_ff, use_window_array=True),),
            window_array=cfg.layer_windows()))
    elif cfg.encoder_layers:  # enc-dec decoder
        dec.append(GroupDef("dec", cfg.num_layers, (
            SubBlockDef(ATTN, ffn="none", theta=cfg.rope_theta),
            SubBlockDef(XATTN, d_ff=cfg.d_ff, theta=cfg.rope_theta))))
    elif cfg.xattn_every:
        n_super, rem = divmod(cfg.num_layers, cfg.xattn_every)
        assert rem == 0, cfg.name
        subs = tuple(SubBlockDef(ATTN, d_ff=cfg.d_ff, theta=cfg.rope_theta)
                     for _ in range(cfg.xattn_every - 1))
        subs += (SubBlockDef(XATTN, d_ff=cfg.d_ff, gated=True,
                             theta=cfg.rope_theta),)
        dec.append(GroupDef("vsuper", n_super, subs))
    elif cfg.num_experts:
        if cfg.first_dense_layers:
            dec.append(GroupDef("dense0", cfg.first_dense_layers, (
                SubBlockDef(ATTN, d_ff=cfg.dense_d_ff or cfg.d_ff,
                            theta=cfg.rope_theta),)))
        rest = cfg.num_layers - cfg.first_dense_layers
        if cfg.moe_every > 1:
            n_super, rem = divmod(rest, cfg.moe_every)
            assert rem == 0, cfg.name
            subs = tuple(SubBlockDef(ATTN, d_ff=cfg.dense_d_ff or cfg.d_ff,
                                     theta=cfg.rope_theta)
                         for _ in range(cfg.moe_every - 1))
            subs += (SubBlockDef(ATTN, ffn="moe", d_ff=cfg.d_ff,
                                 theta=cfg.rope_theta),)
            dec.append(GroupDef("msuper", n_super, subs))
        else:
            dec.append(GroupDef("moe", rest, (
                SubBlockDef(ATTN, ffn="moe", d_ff=cfg.d_ff,
                            theta=cfg.rope_theta),)))
    elif cfg.global_every:
        n_super, rem = divmod(cfg.num_layers, cfg.global_every)
        local = SubBlockDef(ATTN, window=w, d_ff=cfg.d_ff,
                            theta=cfg.rope_theta)
        glob = SubBlockDef(ATTN, window=GLOBAL_WINDOW, d_ff=cfg.d_ff,
                           theta=cfg.rope_theta_global or cfg.rope_theta)
        dec.append(GroupDef("gsuper", n_super,
                            (local,) * (cfg.global_every - 1) + (glob,)))
        if rem:
            dec.append(GroupDef("gtail", rem, (local,)))
    else:
        dec.append(GroupDef("dec", cfg.num_layers, (
            SubBlockDef(ATTN, window=w, d_ff=cfg.d_ff,
                        theta=cfg.rope_theta),)))
    return dec, enc


# ---------------------------------------------------------------------------


class Model:
    def __init__(self, cfg: ArchConfig, mesh: Optional[Mesh] = None,
                 q_chunk: Optional[int] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.q_chunk = cfg.attn_q_chunk if q_chunk is None else q_chunk
        self.logits_dtype = jnp.bfloat16 \
            if cfg.attn_logits_dtype == "bf16" else jnp.float32
        self.ssm_scan_dtype = jnp.bfloat16 \
            if cfg.ssm_scan_dtype == "bf16" else jnp.float32
        self.mlstm_dtype = jnp.bfloat16 \
            if cfg.mlstm_dtype == "bf16" else jnp.float32
        self.dec_groups, self.enc_groups = build_groups(cfg)

    # --- moe plumbing -----------------------------------------------------
    def _moe_kwargs(self):
        mesh = self.mesh
        assert mesh is not None, "MoE archs need a mesh"
        names = mesh.axis_names
        batch_axes = tuple(a for a in ("pod", "data") if a in names)
        fsdp_axes: Tuple[str, ...] = ()
        if self.cfg.use_fsdp and "data" in names:
            fsdp_axes = ("data",)
            if self.cfg.use_pod_fsdp and "pod" in names:
                fsdp_axes = ("data", "pod")
        # only keep fsdp axes that divide the expert F dim
        f = self.cfg.d_ff
        kept = []
        for a in fsdp_axes:
            sz = mesh.shape[a]
            if f % sz == 0:
                kept.append(a)
                f //= sz
        return dict(top_k=self.cfg.top_k, num_experts=self.cfg.num_experts,
                    capacity_factor=self.cfg.capacity_factor, mesh=mesh,
                    batch_axes=batch_axes, fsdp_axes=tuple(kept),
                    comm=self.cfg.moe_comms,
                    gather_dtype=self.cfg.expert_gather_dtype)

    # --- init ---------------------------------------------------------------
    def _init_sub(self, key, s: SubBlockDef):
        cfg = self.cfg
        if s.kind == MLSTM:
            return mlstm_init(key, cfg.d_model, cfg.num_heads, cfg.head_dim)
        if s.kind == SLSTM:
            return slstm_init(key, cfg.d_model, cfg.num_heads, cfg.head_dim)
        k1, k2 = jax.random.split(key)
        if s.kind == HYMBA:
            p = blocks.hymba_init(k1, cfg.d_model, cfg.num_heads,
                                  cfg.num_kv_heads, cfg.head_dim,
                                  cfg.ssm_d_inner, cfg.ssm_state)
        elif s.kind == XATTN:
            p = blocks.xattn_init(k1, cfg.d_model, cfg.num_heads,
                                  cfg.num_kv_heads, cfg.head_dim, s.gated)
        else:  # attn / enc
            p = blocks.attn_init(k1, cfg.d_model, cfg.num_heads,
                                 cfg.num_kv_heads, cfg.head_dim)
        p.update(blocks.ffn_init(k2, cfg.d_model, s.d_ff, s.ffn,
                                 cfg.num_experts))
        return p

    def _init_group(self, key, g: GroupDef):
        def one(k):
            ks = jax.random.split(k, len(g.subs))
            return tuple(self._init_sub(ks[i], s)
                         for i, s in enumerate(g.subs))
        return jax.vmap(one)(jax.random.split(key, g.count))

    def init(self, key: Array):
        cfg = self.cfg
        ks = jax.random.split(key, 4 + len(self.dec_groups)
                              + len(self.enc_groups))
        params: Dict[str, Any] = {
            "emb": dense_init(ks[0], (cfg.vocab_size, cfg.d_model)),
            "final_norm": rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["unemb"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size))
        i = 2
        for g in self.dec_groups:
            params[f"dec_{g.name}"] = self._init_group(ks[i], g)
            i += 1
        for g in self.enc_groups:
            params[f"enc_{g.name}"] = self._init_group(ks[i], g)
            i += 1
        if self.enc_groups:
            params["enc_norm"] = rmsnorm_init(cfg.d_model)
        if cfg.num_shared_experts:
            from repro.models.layers import swiglu_init
            params["shared_ffn"] = swiglu_init(
                ks[-1], cfg.d_model, cfg.d_ff * cfg.num_shared_experts)
        return params

    def init_abstract(self):
        return jax.eval_shape(self.init, jax.ShapeDtypeStruct((2,), jnp.uint32))

    # --- caches ---------------------------------------------------------------
    def _entry_shape(self, g: GroupDef, s: SubBlockDef, batch: int,
                     max_len: int,
                     paged: Optional[cache_lib.PageSpec] = None
                     ) -> Dict[str, Tuple]:
        cfg = self.cfg
        if s.kind == MLSTM:
            return {"C": ((g.count, batch, cfg.num_heads, cfg.head_dim,
                           cfg.head_dim), jnp.float32),
                    "n": ((g.count, batch, cfg.num_heads, cfg.head_dim),
                          jnp.float32),
                    "m": ((g.count, batch, cfg.num_heads), jnp.float32)}
        if s.kind == SLSTM:
            sh = (g.count, batch, cfg.num_heads, cfg.head_dim)
            return {k: (sh, jnp.float32) for k in ("c", "n", "h", "m")}
        out: Dict[str, Tuple] = {}
        if s.kind in (ATTN, HYMBA):
            wl = max_len if s.use_window_array else \
                cache_lib.cache_len_for(s.window, max_len)
            if paged is not None and wl >= max_len:
                # page exactly the entries whose dense form reserves the
                # full max_len; windowed rings are already proportional
                out["k"] = ((g.count, paged.num_blocks, paged.block_size,
                             cfg.num_kv_heads, cfg.head_dim), jnp.bfloat16)
                out["v"] = out["k"]
                out["pos"] = ((batch, paged.logical_len(max_len)),
                              jnp.int32)
                out["btab"] = ((batch, paged.logical_blocks(max_len)),
                               jnp.int32)
            else:
                out["k"] = ((g.count, batch, wl, cfg.num_kv_heads,
                             cfg.head_dim), jnp.bfloat16)
                out["v"] = out["k"]
                out["pos"] = ((batch, wl), jnp.int32)
        if s.kind == XATTN:
            n = cfg.num_image_tokens or cfg.src_seq_len
            out["k"] = ((g.count, batch, n, cfg.num_kv_heads, cfg.head_dim),
                        jnp.bfloat16)
            out["v"] = out["k"]
        if s.kind == HYMBA:
            out["h"] = ((g.count, batch, cfg.ssm_d_inner, cfg.ssm_state),
                        jnp.float32)
            out["conv"] = ((g.count, batch, 3, cfg.ssm_d_inner), jnp.float32)
        return out

    def cache_specs(self, batch: int, max_len: int,
                    paged: Optional[cache_lib.PageSpec] = None):
        specs = {}
        for g in self.dec_groups:
            for si, s in enumerate(g.subs):
                ent = self._entry_shape(g, s, batch, max_len, paged)
                specs[f"{g.name}_{si}"] = {
                    k: jax.ShapeDtypeStruct(sh, dt)
                    for k, (sh, dt) in ent.items()}
        return specs

    def init_cache(self, batch: int, max_len: int,
                   paged: Optional[cache_lib.PageSpec] = None):
        def mk(sds):
            if sds.dtype == jnp.int32:
                return jnp.full(sds.shape, -1, jnp.int32)
            init = -jnp.inf if False else 0.0
            return jnp.zeros(sds.shape, sds.dtype)
        specs = self.cache_specs(batch, max_len, paged)
        out = jax.tree.map(mk, specs)
        # m-states start at -inf
        for name, ent in out.items():
            if "m" in ent and ent["m"].dtype == jnp.float32 \
                    and name.startswith(("xlstm",)):
                ent["m"] = jnp.full_like(ent["m"], -jnp.inf)
        return out

    # --- forward ---------------------------------------------------------------
    def _apply_sub(self, s: SubBlockDef, p, h, entry, pos, ctx, mode,
                   window_override=None):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        dims = dict(heads=cfg.num_heads, kv_heads=cfg.num_kv_heads,
                    dh=cfg.head_dim)
        if s.kind == MLSTM:
            st = None if mode == "train" else (entry["C"], entry["n"],
                                               entry["m"])
            h, st2 = mlstm_forward(p, h, st, heads=cfg.num_heads,
                                   dh=cfg.head_dim,
                                   chunk=cfg.mlstm_chunk,
                                   compute_dtype=self.mlstm_dtype)
            new = None if mode == "train" else \
                {"C": st2[0], "n": st2[1], "m": st2[2]}
            return h, new, aux
        if s.kind == SLSTM:
            st = None if mode == "train" else (entry["c"], entry["n"],
                                               entry["h"], entry["m"])
            h, st2 = slstm_forward(p, h, st, heads=cfg.num_heads,
                                   dh=cfg.head_dim,
                                   compute_dtype=self.mlstm_dtype)
            new = None if mode == "train" else dict(
                zip(("c", "n", "h", "m"), st2))
            return h, new, aux
        if s.kind == "enc":
            from repro.models.layers import attention as attn_fn
            xn = rmsnorm(p["norm"], h)
            q, k, v = blocks._qkv(p, xn, xn, **dims)
            zeros = jnp.zeros(h.shape[:2], jnp.int32)
            o = attn_fn(q, k, v, zeros, zeros, causal=False,
                        q_chunk=self.q_chunk)
            B, C = h.shape[:2]
            h = h + o.reshape(B, C, -1) @ p["wo"]
            h, _ = blocks.apply_ffn(p, h, kind=s.ffn,
                                    moe_kwargs=None, mode=mode)
            return h, None, aux
        if s.kind == XATTN:
            media = ctx.get("media")
            if media is not None:
                mkv = blocks.media_kv_of(p, media, cfg.num_kv_heads,
                                         cfg.head_dim)
                new_media = mkv
            else:
                mkv = {"k": entry["k"], "v": entry["v"]}
                new_media = None
            o = blocks.cross_attention(p, h, mkv, **dims)
            if s.gated:
                o = o * jnp.tanh(p["gate_attn"]).astype(o.dtype)
            h = h + o
            moe_kwargs = self._moe_kwargs() if s.ffn == "moe" else None
            h2, aux = blocks.apply_ffn(p, h, kind=s.ffn,
                                       moe_kwargs=moe_kwargs, mode=mode)
            if s.gated and s.ffn != "none":
                h = h + (h2 - h) * jnp.tanh(p["gate_ffn"]).astype(h.dtype)
            else:
                h = h2
            new = None
            if mode != "train":
                new = {"k": new_media["k"] if new_media else entry["k"],
                       "v": new_media["v"] if new_media else entry["v"]}
            return h, new, aux
        # ATTN / HYMBA
        window = window_override if window_override is not None else s.window
        kv = None
        if mode != "train":
            kv = {"k": entry["k"], "v": entry["v"], "pos": entry["pos"]}
            if "btab" in entry:
                kv["btab"] = entry["btab"]
        o, new_kv = blocks.self_attention(
            p, h, pos, kv, window=window, theta=s.theta, mode=mode,
            q_chunk=self.q_chunk, logits_dtype=self.logits_dtype, **dims)
        if s.kind == HYMBA:
            xn = rmsnorm(p["norm"], h)
            so, st2 = ssm_forward(
                p["ssm"], xn,
                None if mode == "train" else (entry["h"], entry["conv"]),
                d_inner=cfg.ssm_d_inner, state=cfg.ssm_state,
                scan_dtype=self.ssm_scan_dtype)
            o = 0.5 * (rmsnorm(p["anorm"], o) + rmsnorm(p["snorm"], so))
        h = h + o
        moe_kwargs = self._moe_kwargs() if s.ffn == "moe" else None
        h, aux = blocks.apply_ffn(p, h, kind=s.ffn, moe_kwargs=moe_kwargs,
                                  mode=mode)
        new = None
        if mode != "train":
            new = dict(new_kv) if new_kv else {}
            if s.kind == HYMBA:
                new["h"], new["conv"] = st2[0], st2[1]
        return h, new, aux

    def _run_group(self, g: GroupDef, gparams, h, entries, pos, ctx, mode):
        """entries: dict sub_idx -> cache entry (with group-level 'pos'
        threaded in).  Returns (h, new entries, aux)."""
        cfg = self.cfg
        train = mode == "train"
        # per-layer xs: params + scanned cache leaves + window array.
        # 'pos' and 'btab' are group-level (identical for every layer in
        # the scan) and threaded around it, not through it.
        cache_xs = ()
        if not train:
            cache_xs = tuple(
                {k: v for k, v in entries[si].items()
                 if k not in ("pos", "btab")}
                for si in range(len(g.subs)))
        warr = jnp.asarray(g.window_array, jnp.int32) if g.window_array \
            else None
        pos_by_sub = [entries[si].get("pos") if not train else None
                      for si in range(len(g.subs))]
        btab_by_sub = [entries[si].get("btab") if not train else None
                       for si in range(len(g.subs))]

        def body(carry, xs):
            h, aux = carry
            if warr is not None:
                if train:
                    ps, wv = xs
                    cs = ()
                else:
                    ps, cs, wv = xs
            else:
                wv = None
                if train:
                    ps = xs
                    cs = ()
                else:
                    ps, cs = xs
            new_cs = []
            for si, s in enumerate(g.subs):
                entry = None
                if not train:
                    entry = dict(cs[si])
                    if pos_by_sub[si] is not None:
                        entry["pos"] = pos_by_sub[si]
                    if btab_by_sub[si] is not None:
                        entry["btab"] = btab_by_sub[si]
                h, new, a = self._apply_sub(s, ps[si], h, entry, pos, ctx,
                                            mode, window_override=wv)
                aux = aux + a
                if not train:
                    new_cs.append({k: v for k, v in (new or {}).items()
                                   if k not in ("pos", "btab")})
            return (h, aux), tuple(new_cs)

        if cfg.remat:
            policy = None
            if cfg.remat_policy == "dots":
                policy = jax.checkpoint_policies.dots_saveable
            body = jax.checkpoint(body, policy=policy)
        if warr is not None:
            xs = (gparams, warr) if train else (gparams, cache_xs, warr)
        else:
            xs = gparams if train else (gparams, cache_xs)
        (h, aux), new_cache_xs = lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                                          xs)
        new_entries = {}
        if not train:
            for si, s in enumerate(g.subs):
                ent = dict(new_cache_xs[si])
                if pos_by_sub[si] is not None:
                    # group-level position update (same for all layers);
                    # masked scatter drops padded (-1) positions
                    ent["pos"] = cache_lib.scatter_ring(
                        pos_by_sub[si], pos, pos)
                if btab_by_sub[si] is not None:
                    ent["btab"] = btab_by_sub[si]   # host-leased, read-only
                new_entries[si] = ent
        return h, new_entries, aux

    def _encode(self, params, src_embeds):
        h = src_embeds
        for g in self.enc_groups:
            h, _, _ = self._run_group(g, params[f"enc_{g.name}"], h, {},
                                      jnp.zeros(h.shape[:2], jnp.int32),
                                      {}, "train")
        return rmsnorm(params["enc_norm"], h)

    def _backbone(self, params, h, pos, cache, ctx, mode):
        aux_total = jnp.zeros((), jnp.float32)
        new_cache = {}
        for g in self.dec_groups:
            entries = {}
            if mode != "train":
                entries = {si: cache[f"{g.name}_{si}"]
                           for si in range(len(g.subs))}
            h, new_entries, aux = self._run_group(
                g, params[f"dec_{g.name}"], h, entries, pos, ctx, mode)
            aux_total = aux_total + aux
            for si, ent in new_entries.items():
                new_cache[f"{g.name}_{si}"] = ent
        return rmsnorm(params["final_norm"], h), new_cache, aux_total

    def _unemb(self, params):
        if self.cfg.tie_embeddings:
            return params["emb"].T
        return params["unemb"]

    # --- public entry points ---------------------------------------------------
    def train_loss(self, params, batch) -> Tuple[Array, Dict[str, Array]]:
        """Loss for one microbatch: batch = {'tokens','labels', [extras]}."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = jnp.take(params["emb"], tokens, axis=0)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        ctx = self._ctx_from(params, batch)
        h, _, aux = self._backbone(params, h, pos, {}, ctx, "train")
        loss = softmax_xent_chunked(h, self._unemb(params), batch["labels"])
        total = loss + 0.01 * aux
        return total, {"xent": loss, "aux": aux}

    def _ctx_from(self, params, batch):
        ctx: Dict[str, Any] = {"media": None}
        if "image_embeds" in batch:
            ctx["media"] = batch["image_embeds"]
        if "src_embeds" in batch:
            ctx["media"] = self._encode(params, batch["src_embeds"])
        return ctx

    def extend(self, params, tokens, positions, cache, extras=None):
        """Process a chunk.  tokens: (B, C); positions: (B,) start positions.
        Returns (logits (B, C, V) of the last chunk only when C==1 else
        last-position logits, new cache)."""
        extras = extras or {}
        B, C = tokens.shape
        h = jnp.take(params["emb"], tokens, axis=0)
        pos = positions[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
        ctx = self._ctx_from(params, extras)
        mode = "decode" if C == 1 else "chunk"
        h, new_cache, _ = self._backbone(params, h, pos, cache, ctx, mode)
        logits = logits_for(h[:, -1:], self._unemb(params))
        return logits, new_cache

    def prefill(self, params, tokens, extras=None, max_len: int = 0):
        """Chunked prefill over the full prompt.  Returns (last logits,
        filled cache).  ``max_len`` sizes the cache (>= prompt length +
        expected decode budget; defaults to the prompt length)."""
        cfg = self.cfg
        B, S = tokens.shape
        chunk = min(cfg.prefill_chunk, S)
        if S % chunk:
            chunk = S
        cache = self.init_cache(B, max(max_len, S))
        extras = extras or {}
        logits = None
        n = S // chunk
        ctx_extras = extras

        def step(carry, i):
            cache = carry
            tok = lax.dynamic_slice_in_dim(tokens, i * chunk, chunk, axis=1)
            start = jnp.full((B,), i * chunk, jnp.int32)
            lg, cache = self.extend(params, tok, start, cache, ctx_extras)
            return cache, lg

        cache, lgs = lax.scan(step, cache, jnp.arange(n))
        return lgs[-1], cache

    def decode_step(self, params, tokens, positions, cache):
        return self.extend(params, tokens, positions, cache, {})

    def serve_step(self, params, tokens, starts, lengths, cache):
        """One serving dispatch over a ragged batch.

        tokens: (B, C); starts: (B,) absolute position of each slot's
        first token; lengths: (B,) valid token count per slot (0 = idle
        slot).  Positions past ``lengths`` are masked to -1, so their
        tokens neither attend nor write to the cache.  Returns (logits
        (B, 1, V) at each slot's last valid token, new cache); idle
        slots' logits are garbage and must be ignored by the caller.
        """
        B, C = tokens.shape
        h = jnp.take(params["emb"], tokens, axis=0)
        off = jnp.arange(C, dtype=jnp.int32)[None]
        pos = jnp.where(off < lengths[:, None], starts[:, None] + off, -1)
        mode = "decode" if C == 1 else "chunk"
        h, new_cache, _ = self._backbone(params, h, pos, cache,
                                         {"media": None}, mode)
        last = jnp.clip(lengths - 1, 0, C - 1)
        hl = jnp.take_along_axis(
            h, last[:, None, None].astype(jnp.int32), axis=1)
        return logits_for(hl, self._unemb(params)), new_cache

    def reset_cache_slots(self, cache, mask):
        """Clear per-slot cache state where ``mask`` (B,) is True so the
        slot can be reused.  pos/btab go to -1; xLSTM stabilizer states
        ('m') to -inf; paged physical pools pass through untouched (their
        blocks are recycled through the host-side pool and overwritten on
        the next lease); everything else is zeroed.  Batch is axis 0 for
        pos/btab and axis 1 (after the layer-count axis) for the rest."""
        def reset_entry(ent):
            paged = "btab" in ent
            out = {}
            for k, v in ent.items():
                if k in ("pos", "btab"):
                    out[k] = jnp.where(mask[:, None],
                                       jnp.full_like(v, -1), v)
                elif paged and k in ("k", "v"):
                    out[k] = v
                else:
                    m = mask.reshape((1, -1) + (1,) * (v.ndim - 2))
                    fill = jnp.full_like(v, -jnp.inf) if k == "m" \
                        else jnp.zeros_like(v)
                    out[k] = jnp.where(m, fill, v)
            return out
        return {name: reset_entry(ent) for name, ent in cache.items()}
