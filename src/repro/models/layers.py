"""Shared neural-net layers: norms, RoPE, attention, SwiGLU, chunked xent.

All functions are pure; parameters are plain pytrees of jnp arrays.
Naming convention for leaves (used by the partition rules in
``repro.models.partition``):

    emb        (V, D)      token embedding
    unemb      (D, V)      output projection
    scale      (D,)        RMSNorm gain
    wq/wk/wv   (D, H*dh)   attention projections
    wo         (H*dh, D)   attention output
    w1/w3      (D, F)      SwiGLU gate/up
    w2         (F, D)      SwiGLU down
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import GLOBAL_WINDOW

Array = jax.Array
DEFAULT_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Initialisation helpers
# ---------------------------------------------------------------------------

def dense_init(key: Array, shape: Tuple[int, ...],
               dtype=DEFAULT_DTYPE, scale: Optional[float] = None) -> Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def rmsnorm_init(d: int, dtype=DEFAULT_DTYPE) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: Array, positions: Array, theta) -> Array:
    """Apply RoPE.  x: (B, T, H, dh); positions: (B, T) int32; theta scalar
    (may be a traced per-layer value)."""
    dh = x.shape[-1]
    half = dh // 2
    freq_exp = jnp.arange(half, dtype=jnp.float32) / half
    inv_freq = jnp.asarray(theta, jnp.float32) ** -freq_exp       # (half,)
    ang = positions.astype(jnp.float32)[..., None] * inv_freq      # (B,T,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (XLA path; the Pallas flash kernel replaces this on real TPU)
# ---------------------------------------------------------------------------

def _attn_chunk(q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array,
                *, window, causal: bool, scale: float,
                logits_dtype=jnp.float32) -> Array:
    """Exact attention for one query chunk.

    q: (B, Tq, Hq, dh); k/v: (B, Tk, Hkv, dh); q_pos: (B, Tq); k_pos: (B, Tk)
    with -1 marking invalid cache slots.  ``window`` may be a traced scalar;
    GLOBAL_WINDOW means unbounded.  ``logits_dtype=bf16`` halves the
    dominant (Tq, Tk) HBM buffer on the XLA path (the flash kernel keeps
    it out of HBM entirely); softmax math stays fp32 either way.
    """
    B, Tq, Hq, dh = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qg = q.reshape(B, Tq, Hkv, g, dh)
    logits = jnp.einsum("bqhgd,bshd->bhgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    valid = (k_pos >= 0)[:, None, :]                                # (B,1,Tk)
    if causal:
        rel = q_pos[:, :, None] - k_pos[:, None, :]                 # (B,Tq,Tk)
        mask = valid & (rel >= 0) & (rel < jnp.asarray(window, jnp.int32))
    else:
        mask = jnp.broadcast_to(valid, (B, Tq, k.shape[1]))
    mask = mask[:, None, None]                                      # (B,1,1,Tq,Tk)
    logits = jnp.where(mask, logits, -1e30).astype(logits_dtype)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Tq, Hq, dh)


def attention(q: Array, k: Array, v: Array, q_pos: Array, k_pos: Array, *,
              window=GLOBAL_WINDOW, causal: bool = True,
              q_chunk: int = 0, logits_dtype=jnp.float32) -> Array:
    """Exact masked attention with optional query chunking (bounds the
    (Tq, Tk) logits buffer; same FLOPs, O(q_chunk*Tk) memory)."""
    B, Tq, Hq, dh = q.shape
    scale = dh ** -0.5
    if q_chunk and Tq > q_chunk and Tq % q_chunk == 0:
        n = Tq // q_chunk
        qr = jnp.moveaxis(q.reshape(B, n, q_chunk, Hq, dh), 1, 0)
        pr = jnp.moveaxis(q_pos.reshape(B, n, q_chunk), 1, 0)

        def step(_, xs):
            qc, pc = xs
            return None, _attn_chunk(qc, k, v, pc, k_pos, window=window,
                                     causal=causal, scale=scale,
                                     logits_dtype=logits_dtype)

        _, out = lax.scan(step, None, (qr, pr))
        return jnp.moveaxis(out, 0, 1).reshape(B, Tq, Hq, dh)
    return _attn_chunk(q, k, v, q_pos, k_pos, window=window, causal=causal,
                       scale=scale, logits_dtype=logits_dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def swiglu_init(key: Array, d: int, f: int, dtype=DEFAULT_DTYPE) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w1": dense_init(k1, (d, f), dtype),
            "w3": dense_init(k2, (d, f), dtype),
            "w2": dense_init(k3, (f, d), dtype)}


def swiglu(params: dict, x: Array) -> Array:
    h = jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])
    return h @ params["w2"]


# ---------------------------------------------------------------------------
# Chunked softmax cross-entropy (avoids materialising (B, S, V) logits)
# ---------------------------------------------------------------------------

def softmax_xent_chunked(h: Array, unemb: Array, labels: Array,
                         chunk: int = 512) -> Array:
    """Mean cross-entropy.  h: (B, S, D); unemb: (D, V); labels: (B, S).

    Scans over sequence chunks so only (B, chunk, V) logits are live at a
    time — the production trick for 200k+ vocabularies."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # fall back for odd smoke-test sizes
    n = S // chunk
    hr = jnp.moveaxis(h.reshape(B, n, chunk, D), 1, 0)
    lr = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)

    def step(acc, xs):
        hc, lc = xs
        logits = (hc @ unemb).astype(jnp.float32)           # (B, chunk, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = lax.scan(step, jnp.zeros((), jnp.float32), (hr, lr))
    return total / (B * S)


def logits_for(h: Array, unemb: Array) -> Array:
    return (h @ unemb).astype(jnp.float32)
