"""Expert-parallel Mixture-of-Experts FFN, routed over the Communicator.

Experts are sharded over the ``model`` mesh axis.  All expert-parallel
communication goes through a model-axis-bound
:class:`~repro.comms.Communicator` — the same swappable, benchmarkable
transport stack (``native`` / ``tree`` / ``serial`` / ``hier`` /
``hier_int8``) that carries every other collective in the repo; there
are no direct ``lax.all_to_all`` calls here.  The transport is selected
by the ``comm`` argument (a registry name, a ``CommSpec``, or a
prebuilt ``Communicator``; ``ArchConfig.moe_comms`` / ``--moe-comms``
upstream), and the ``alltoall`` bench case family watches every option.

Two dispatch modes, trading exchange latency against replicated compute:

* ``scatter`` (train / chunked prefill): tokens are sharded over *all*
  mesh axes (batch over data/pod, sequence over model); each device
  routes its own tokens and exchanges them with the expert owners via
  two ``Communicator.alltoall``s (dispatch + combine).  Fixed
  per-destination capacity, overflow dropped (standard dropping MoE).
  The exchange bytes are explicit in the lowered HLO — exactly what the
  roofline collective term wants to see — and because the all-to-all is
  pure data movement, scatter-mode outputs are *bitwise identical*
  across transports (property-tested in tests/test_alltoall.py).

* ``replicated`` (decode): token counts are tiny (B tokens), so every
  model-rank routes the full local batch, computes only the assignments
  that land on its own experts, and partial results are combined with a
  single ``Communicator.allreduce`` over the model axis.  No all-to-all
  latency on the critical decode path, at the cost of every rank running
  the router on the full batch.

Compute is a batched einsum over the local expert block — FLOPs are
proportional to *active* parameters (x capacity factor), never to the
full expert count.  ``moe_ffn_reference`` is the pure-jnp dense oracle
used by tests.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.comms.communicator import CommSpec, Communicator
from repro.comms.compat import (axis_index, axis_size,
                                shard_map)

Array = jax.Array


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_init(key: Array, d_model: int, d_ff: int, num_experts: int,
             dtype=jnp.bfloat16) -> Dict[str, Array]:
    from repro.models.layers import dense_init
    k1, k2, k3, k4 = jax.random.split(key, 4)
    E, D, F = num_experts, d_model, d_ff
    return {
        "wr": dense_init(k1, (D, E), jnp.float32),
        "we1": dense_init(k2, (E, D, F), dtype),
        "we3": dense_init(k3, (E, D, F), dtype),
        "we2": dense_init(k4, (E, F, D), dtype, scale=F ** -0.5),
    }


def _route(x: Array, wr: Array, top_k: int) -> Tuple[Array, Array, Array]:
    """Router.  x: (T, D) -> (weights (T,k) f32, eids (T,k) i32, probs)."""
    logits = x.astype(jnp.float32) @ wr.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, eids = lax.top_k(probs, top_k)
    weights = vals / jnp.maximum(vals.sum(-1, keepdims=True), 1e-9)
    return weights, eids, probs


def _positions_within(dest: Array, n_dest: int) -> Array:
    """Rank of each element among elements with the same destination.
    dest: (A,) int32 in [0, n_dest)."""
    oh = (dest[:, None] == jnp.arange(n_dest, dtype=dest.dtype)[None, :])
    pos = jnp.cumsum(oh.astype(jnp.int32), axis=0) - 1
    return jnp.take_along_axis(pos, dest[:, None].astype(jnp.int32), axis=1)[:, 0]


def _aux_loss(probs: Array, eids: Array, num_experts: int) -> Array:
    """Switch-style load-balancing loss (local shard contribution)."""
    T = probs.shape[0]
    top1 = eids[:, 0]
    frac = jnp.zeros((num_experts,), jnp.float32).at[top1].add(1.0) / T
    mean_prob = probs.mean(0)
    return num_experts * jnp.sum(frac * mean_prob)


def _expert_compute(buf: Array, w1: Array, w3: Array, w2: Array) -> Array:
    """buf: (E_loc, Ce, D) -> (E_loc, Ce, D) via per-expert SwiGLU."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1)) \
        * jnp.einsum("ecd,edf->ecf", buf, w3)
    return jnp.einsum("ecf,efd->ecd", h, w2)


def _gather_fsdp(w: Array, axis: int, fsdp_axes: Sequence[str],
                 gather_dtype: str = "bf16") -> Array:
    """All-gather FSDP-sharded expert weights at use.

    ``gather_dtype='int8'`` quantizes the local block (per-channel scales
    along the gathered axis) before the gather — halves the dominant
    collective bytes of MoE training; dequantized blockwise after."""
    if not fsdp_axes:
        return w
    if gather_dtype != "int8":
        for a in fsdp_axes:
            w = lax.all_gather(w, a, axis=axis, tiled=True)
        return w
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    blk = w.shape[axis]
    nsh = 1
    for a in fsdp_axes:
        q = lax.all_gather(q, a, axis=axis, tiled=True)
        scale = lax.all_gather(scale, a, axis=axis, tiled=True)
        nsh *= axis_size(a)
    shp = q.shape
    split = shp[:axis] + (nsh, blk) + shp[axis + 1:]
    qs = q.reshape(split).astype(jnp.bfloat16)
    ss = scale.reshape(shp[:axis] + (nsh, 1) + shp[axis + 1:]
                       ).astype(jnp.bfloat16)
    return (qs * ss).reshape(shp)


# ---------------------------------------------------------------------------
# scatter mode (train / prefill)
# ---------------------------------------------------------------------------

def _moe_scatter_local(x: Array, wr: Array, w1: Array, w3: Array, w2: Array,
                       *, top_k: int, num_experts: int, model_size: int,
                       capacity_factor: float,
                       fsdp_axes: Sequence[str],
                       model_axis: str, comm: Communicator,
                       gather_dtype: str = "bf16") -> Tuple[Array, Array]:
    """Per-device body (inside shard_map).  x: (Tl, D) local tokens."""
    Tl, D = x.shape
    M, E = model_size, num_experts
    E_loc = E // M
    w1 = _gather_fsdp(w1, 2, fsdp_axes, gather_dtype)
    w3 = _gather_fsdp(w3, 2, fsdp_axes, gather_dtype)
    w2 = _gather_fsdp(w2, 1, fsdp_axes, gather_dtype)

    weights, eids, probs = _route(x, wr, top_k)
    aux = _aux_loss(probs, eids, E)

    A = Tl * top_k
    eids_f = eids.reshape(A)
    w_f = weights.reshape(A)
    tok_f = jnp.arange(A, dtype=jnp.int32) // top_k
    dst = eids_f // E_loc
    leid = eids_f % E_loc

    C = _round_up(max(int(math.ceil(A / M * capacity_factor)), 8), 8)
    pos = _positions_within(dst, M)
    keep = pos < C
    slot = jnp.where(keep, dst * C + pos, M * C)

    send_x = jnp.zeros((M * C, D), x.dtype).at[slot].set(
        x[tok_f], mode="drop")
    send_leid = jnp.full((M * C,), -1, jnp.int32).at[slot].set(
        leid, mode="drop")

    # dispatch: per-destination blocks -> expert owners, through the
    # Communicator's swappable alltoall (pure data movement — outputs
    # are transport-invariant bit-for-bit)
    recv_x, recv_leid = comm.alltoall((send_x, send_leid))

    R = M * C
    Ce = _round_up(max(int(math.ceil(R / E_loc * capacity_factor)), 8), 8)
    valid = recv_leid >= 0
    pos2 = _positions_within(jnp.where(valid, recv_leid, 0), E_loc)
    keep2 = valid & (pos2 < Ce)
    slot2 = jnp.where(keep2, recv_leid * Ce + pos2, E_loc * Ce)

    ebuf = jnp.zeros((E_loc * Ce, D), x.dtype).at[slot2].set(
        recv_x, mode="drop")
    y = _expert_compute(ebuf.reshape(E_loc, Ce, D), w1, w3, w2)
    y = y.reshape(E_loc * Ce, D)

    out_r = jnp.where(keep2[:, None],
                      jnp.take(y, jnp.minimum(slot2, E_loc * Ce - 1), axis=0),
                      0).astype(x.dtype)
    back = comm.alltoall(out_r)          # combine: results -> token owners

    y_a = jnp.where(keep[:, None],
                    jnp.take(back, jnp.minimum(slot, M * C - 1), axis=0),
                    0)
    y_tok = jnp.sum(y_a.reshape(Tl, top_k, D)
                    * w_f.reshape(Tl, top_k, 1).astype(x.dtype), axis=1)
    return y_tok, aux


# ---------------------------------------------------------------------------
# replicated mode (decode)
# ---------------------------------------------------------------------------

def _moe_replicated_local(x: Array, wr: Array, w1: Array, w3: Array,
                          w2: Array, *, top_k: int, num_experts: int,
                          model_size: int, fsdp_axes: Sequence[str],
                          model_axis: str, comm: Communicator,
                          gather_dtype: str = "bf16") -> Tuple[Array, Array]:
    """Decode path: x (Tl, D) replicated over the model axis; each rank
    computes only assignments hitting its local experts; the
    Communicator's allreduce combines the partial results."""
    Tl, D = x.shape
    M, E = model_size, num_experts
    E_loc = E // M
    my = axis_index(model_axis)
    w1 = _gather_fsdp(w1, 2, fsdp_axes, gather_dtype)
    w3 = _gather_fsdp(w3, 2, fsdp_axes, gather_dtype)
    w2 = _gather_fsdp(w2, 1, fsdp_axes, gather_dtype)

    weights, eids, _ = _route(x, wr, top_k)
    A = Tl * top_k
    eids_f = eids.reshape(A)
    w_f = weights.reshape(A)
    mine = (eids_f // E_loc) == my
    leid = eids_f % E_loc

    Ce = _round_up(max(A, 8), 8)  # no drops on the decode path
    pos = _positions_within(jnp.where(mine, leid, 0), E_loc)
    slot = jnp.where(mine, leid * Ce + pos, E_loc * Ce)
    tok_f = jnp.arange(A, dtype=jnp.int32) // top_k

    ebuf = jnp.zeros((E_loc * Ce, D), x.dtype).at[slot].set(
        x[tok_f], mode="drop")
    y = _expert_compute(ebuf.reshape(E_loc, Ce, D), w1, w3, w2)
    y = y.reshape(E_loc * Ce, D)

    y_a = jnp.where(mine[:, None],
                    jnp.take(y, jnp.minimum(slot, E_loc * Ce - 1), axis=0), 0)
    y_tok = jnp.sum(y_a.reshape(Tl, top_k, D)
                    * w_f.reshape(Tl, top_k, 1).astype(x.dtype), axis=1)
    y_tok = comm.allreduce(y_tok)
    return y_tok, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------

def moe_ffn(params: Dict[str, Array], x: Array, *, top_k: int,
            num_experts: int, capacity_factor: float, mesh: Mesh,
            batch_axes: Tuple[str, ...], model_axis: str = "model",
            fsdp_axes: Tuple[str, ...] = (), mode: str = "scatter",
            comm: Union[str, CommSpec, Communicator, None] = None,
            gather_dtype: str = "bf16") -> Tuple[Array, Array]:
    """MoE FFN.  x: (B, T, D) -> (B, T, D), aux-loss scalar.

    ``comm`` picks the transport carrying the expert exchange: a
    registry name ('native', 'tree', ...), a ``CommSpec``, or a prebuilt
    model-axis ``Communicator``; None means 'native'.  In scatter mode
    the T axis must be divisible by the model-axis size.
    """
    B, T, D = x.shape
    M = mesh.shape[model_axis]
    if not isinstance(comm, Communicator):
        comm = Communicator.for_mesh(mesh, comm, axes=(model_axis,))
    expert_spec1 = P(model_axis, None, fsdp_axes if fsdp_axes else None)
    expert_spec2 = P(model_axis, fsdp_axes if fsdp_axes else None, None)

    if mode == "scatter":
        x_spec = P(batch_axes, model_axis, None)
        body = functools.partial(
            _moe_scatter_local, top_k=top_k, num_experts=num_experts,
            model_size=M, capacity_factor=capacity_factor,
            fsdp_axes=fsdp_axes, model_axis=model_axis, comm=comm,
            gather_dtype=gather_dtype)
    else:
        x_spec = P(batch_axes, None, None)
        body = functools.partial(
            _moe_replicated_local, top_k=top_k, num_experts=num_experts,
            model_size=M, fsdp_axes=fsdp_axes, model_axis=model_axis,
            comm=comm, gather_dtype=gather_dtype)

    def local(x3, wr, w1, w3_, w2):
        b, t, d = x3.shape
        y, aux = body(x3.reshape(b * t, d), wr, w1, w3_, w2)
        # aux: average over every device that computed a distinct shard
        aux = lax.pmean(aux, batch_axes) if batch_axes else aux
        if mode == "scatter":
            aux = lax.pmean(aux, model_axis)
        return y.reshape(b, t, d), aux

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P(None, None), expert_spec1, expert_spec1,
                  expert_spec2),
        out_specs=(x_spec, P()))
    return fn(x, params["wr"], params["we1"], params["we3"], params["we2"])


# ---------------------------------------------------------------------------
# dense oracle (tests)
# ---------------------------------------------------------------------------

def moe_ffn_reference(params: Dict[str, Array], x: Array, *, top_k: int,
                      num_experts: int) -> Tuple[Array, Array]:
    """Dense-masked reference: every expert on every token, masked combine.
    O(E) FLOPs — only for tiny test shapes."""
    B, T, D = x.shape
    xf = x.reshape(B * T, D)
    weights, eids, probs = _route(xf, params["wr"], top_k)
    aux = _aux_loss(probs, eids, num_experts)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", xf, params["we1"])) \
        * jnp.einsum("td,edf->etf", xf, params["we3"])
    y_all = jnp.einsum("etf,efd->etd", h, params["we2"])   # (E, T, D)
    comb = jnp.zeros((B * T, num_experts), jnp.float32)
    comb = jax.vmap(lambda c, e, w: c.at[e].add(w))(comb, eids, weights)
    y = jnp.einsum("te,etd->td", comb.astype(x.dtype), y_all)
    return y.reshape(B, T, D), aux
