"""KV / recurrent-state cache machinery.

A *cache entry* serves one stack of ``count`` identical layers (the scan
group).  KV entries are ring buffers of length ``cache_len`` =
min(max_len, window): sliding-window layers keep only their window, global
layers the full sequence.  Slot positions are tracked explicitly in
``pos`` (shape (B, cache_len), -1 = empty) so attention masks are always
derived from true token positions — this makes ring wraparound, chunked
prefill and per-sequence decode offsets all fall out of one code path.

Update discipline (see repro/models/blocks.py):
  * chunk extend (C > 1): attend over [old cache ++ chunk], then write the
    chunk into the ring ("attend-then-update" — never clobbers keys the
    chunk still needs);
  * decode (C == 1): write first, then attend over the ring only
    ("update-then-attend" — avoids a full cache copy per token; safe
    because the overwritten slot is exactly window positions old).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def kv_entry(count: int, batch: int, cache_len: int, kv_heads: int,
             head_dim: int, dtype=jnp.bfloat16) -> Dict[str, Array]:
    return {
        "k": jnp.zeros((count, batch, cache_len, kv_heads, head_dim), dtype),
        "v": jnp.zeros((count, batch, cache_len, kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def kv_entry_specs(count, batch, cache_len, kv_heads, head_dim,
                   dtype=jnp.bfloat16):
    return {
        "k": jax.ShapeDtypeStruct((count, batch, cache_len, kv_heads, head_dim), dtype),
        "v": jax.ShapeDtypeStruct((count, batch, cache_len, kv_heads, head_dim), dtype),
        "pos": jax.ShapeDtypeStruct((batch, cache_len), jnp.int32),
    }


def _write_ring(buf: Array, new: Array, start: Array) -> Array:
    """Write ``new`` (B, C, ...) into ring ``buf`` (B, W, ...) at per-batch
    slot ``start`` (B,) int32.  Requires C == W, or C | W (no wraparound)."""
    B, W = buf.shape[0], buf.shape[1]
    C = new.shape[1]
    if C >= W:
        return lax.dynamic_update_slice_in_dim(buf, new[:, -W:], 0, axis=1)

    def upd(b, n, s):
        return lax.dynamic_update_slice_in_dim(b, n, s, axis=0)

    return jax.vmap(upd)(buf, new, start)


def update_kv(entry_k: Array, entry_v: Array, pos: Array,
              new_k: Array, new_v: Array, q_pos: Array
              ) -> Tuple[Array, Array, Array]:
    """Write a chunk into one layer's ring.

    entry_k/v: (B, W, H, dh); pos: (B, W); new_k/v: (B, C, H, dh);
    q_pos: (B, C) absolute positions of the chunk tokens.
    """
    W = entry_k.shape[1]
    C = new_k.shape[1]
    start = q_pos[:, 0] % W if C < W else q_pos[:, 0] * 0
    k2 = _write_ring(entry_k, new_k, start)
    v2 = _write_ring(entry_v, new_v, start)
    pos2 = _write_ring(pos, q_pos[:, -W:] if C >= W else q_pos, start)
    return k2, v2, pos2


def cache_len_for(window: int, max_len: int) -> int:
    from repro.configs.base import GLOBAL_WINDOW
    if window >= GLOBAL_WINDOW or window <= 0:
        return max_len
    return min(window, max_len)


# --- recurrent-state entries (xLSTM / Mamba-style) -------------------------

def mlstm_entry(count, batch, heads, dh, dtype=jnp.float32):
    return {
        "C": jnp.zeros((count, batch, heads, dh, dh), dtype),
        "n": jnp.zeros((count, batch, heads, dh), dtype),
        "m": jnp.full((count, batch, heads), -jnp.inf, dtype),
    }


def slstm_entry(count, batch, heads, dh, dtype=jnp.float32):
    return {
        "c": jnp.zeros((count, batch, heads, dh), dtype),
        "n": jnp.zeros((count, batch, heads, dh), dtype),
        "h": jnp.zeros((count, batch, heads, dh), dtype),
        "m": jnp.full((count, batch, heads, dh), -jnp.inf, dtype),
    }


def ssm_entry(count, batch, d_inner, state, conv_taps=3, dtype=jnp.float32):
    return {
        "h": jnp.zeros((count, batch, d_inner, state), dtype),
        "conv": jnp.zeros((count, batch, conv_taps, d_inner), dtype),
    }
