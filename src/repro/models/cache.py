"""KV / recurrent-state cache machinery.

A *cache entry* serves one stack of ``count`` identical layers (the scan
group).  KV entries come in two storage layouts:

* **ring** — a per-slot buffer of length ``cache_len`` = min(max_len,
  window): sliding-window layers keep only their window, global layers
  the full sequence.
* **paged** — a *shared* physical pool of fixed-size blocks
  ((count, num_blocks, block_size, ...)) plus a per-slot block table
  ``btab`` (B, max_blocks) mapping logical block -> physical block (-1 =
  unleased).  Slots lease blocks on demand (see repro/serve/pool.py)
  instead of reserving ``max_len`` rings up front; the attention path
  gathers/scatters through the table.  Used for full-length entries
  where the dense reservation is the memory cost worth paging.

Slot positions are tracked explicitly in ``pos`` (shape (B, L), -1 =
empty) so attention masks are always derived from true token positions —
ring wraparound, chunked prefill, paging and per-sequence decode offsets
all fall out of one code path.

Writes are masked per-token scatters (``scatter_ring``): tokens with
``q_pos < 0`` are dropped entirely, which lets a serving batch mix
prefill chunks, single decode tokens and idle slots in one dispatch
without clobbering live cache lines.

Update discipline (see repro/models/blocks.py):
  * chunk extend (C > 1): attend over [old cache ++ chunk], then write the
    chunk ("attend-then-update" — never clobbers keys the chunk still
    needs);
  * decode (C == 1): write first, then attend over the cache only
    ("update-then-attend" — avoids a full cache copy per token; safe
    because the overwritten slot is exactly window positions old).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PageSpec:
    """Paged-pool geometry: ``num_blocks`` physical blocks of
    ``block_size`` tokens shared by all slots of an entry."""

    block_size: int
    num_blocks: int

    def logical_blocks(self, max_len: int) -> int:
        return -(-max_len // self.block_size)        # ceil

    def logical_len(self, max_len: int) -> int:
        return self.logical_blocks(max_len) * self.block_size


def kv_entry(count: int, batch: int, cache_len: int, kv_heads: int,
             head_dim: int, dtype=jnp.bfloat16) -> Dict[str, Array]:
    return {
        "k": jnp.zeros((count, batch, cache_len, kv_heads, head_dim), dtype),
        "v": jnp.zeros((count, batch, cache_len, kv_heads, head_dim), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


def kv_entry_specs(count, batch, cache_len, kv_heads, head_dim,
                   dtype=jnp.bfloat16):
    return {
        "k": jax.ShapeDtypeStruct((count, batch, cache_len, kv_heads, head_dim), dtype),
        "v": jax.ShapeDtypeStruct((count, batch, cache_len, kv_heads, head_dim), dtype),
        "pos": jax.ShapeDtypeStruct((batch, cache_len), jnp.int32),
    }


def ring_indices(q_pos: Array, W: int) -> Array:
    """Per-token ring write index for chunk positions ``q_pos`` (B, C):
    ``p % W`` for tokens that survive (valid and within the chunk's last
    ``W`` positions — older ones would be overwritten by the same chunk),
    ``W`` (out of range => dropped by ``mode='drop'``) otherwise."""
    valid = q_pos >= 0
    last = jnp.max(jnp.where(valid, q_pos, -1), axis=1, keepdims=True)
    keep = valid & (q_pos > last - W)
    return jnp.where(keep, q_pos % W, W)


def scatter_ring(buf: Array, new: Array, q_pos: Array) -> Array:
    """Masked per-token scatter of ``new`` (B, C, ...) into ring ``buf``
    (B, W, ...): token at absolute position p lands at slot ``p % W``;
    tokens with ``q_pos < 0`` (padding / idle slots) are dropped.  Unlike
    a contiguous dynamic-update-slice this is safe for ragged serving
    batches where only some batch rows carry real tokens."""
    idx = ring_indices(q_pos, buf.shape[1])

    def scat(b, i, n):
        return b.at[i].set(n, mode="drop")

    return jax.vmap(scat)(buf, idx, new)


def update_kv(entry_k: Array, entry_v: Array, pos: Array,
              new_k: Array, new_v: Array, q_pos: Array
              ) -> Tuple[Array, Array, Array]:
    """Write a chunk into one layer's ring.

    entry_k/v: (B, W, H, dh); pos: (B, W); new_k/v: (B, C, H, dh);
    q_pos: (B, C) absolute positions of the chunk tokens (-1 = padding,
    dropped).
    """
    k2 = scatter_ring(entry_k, new_k, q_pos)
    v2 = scatter_ring(entry_v, new_v, q_pos)
    pos2 = scatter_ring(pos, q_pos, q_pos)
    return k2, v2, pos2


# --- paged entries ---------------------------------------------------------


def _flat_pool(buf: Array) -> Array:
    """(num_blocks, bs, ...) physical pool -> (num_blocks * bs, ...)."""
    return buf.reshape((buf.shape[0] * buf.shape[1],) + buf.shape[2:])


def paged_gather(buf: Array, btab: Array) -> Array:
    """Materialize the logical per-slot view of a paged pool.

    buf: (num_blocks, bs, H, dh) one layer's physical pool;
    btab: (B, M) block table.  Returns (B, M * bs, H, dh) where logical
    token position p of slot b lives at index p; unleased blocks read as
    zeros (their ``pos`` entries are -1, so attention masks them out).
    """
    bs = buf.shape[1]
    flat = _flat_pool(buf)
    base = jnp.where(btab >= 0, btab * bs, flat.shape[0])     # OOB => fill
    idx = base[:, :, None] + jnp.arange(bs, dtype=btab.dtype)[None, None]
    idx = idx.reshape(btab.shape[0], -1)
    return jnp.take(flat, idx, axis=0, mode="fill", fill_value=0)


def paged_scatter(buf: Array, btab: Array, new: Array, q_pos: Array
                  ) -> Array:
    """Write chunk tokens into the physical pool through the block table.

    buf: (num_blocks, bs, H, dh); btab: (B, M); new: (B, C, H, dh);
    q_pos: (B, C) logical positions (-1 = padding).  Tokens whose
    position is invalid or whose logical block is unleased are dropped —
    they can never land in another slot's blocks.
    """
    bs = buf.shape[1]
    flat = _flat_pool(buf)
    size = flat.shape[0]
    lb = jnp.where(q_pos >= 0, q_pos // bs, 0)
    blk = jnp.take_along_axis(btab, lb, axis=1)               # (B, C)
    phys = jnp.where((q_pos >= 0) & (blk >= 0),
                     blk * bs + q_pos % bs, size)             # size => drop
    flat = flat.at[phys.reshape(-1)].set(
        new.reshape((-1,) + new.shape[2:]), mode="drop")
    return flat.reshape(buf.shape)


def paged_kv_entry(count: int, num_blocks: int, block_size: int,
                   batch: int, max_len: int, kv_heads: int, head_dim: int,
                   dtype=jnp.bfloat16) -> Dict[str, Array]:
    """A paged KV entry: shared physical pool + per-slot block table."""
    M = -(-max_len // block_size)
    L = M * block_size
    return {
        "k": jnp.zeros((count, num_blocks, block_size, kv_heads, head_dim),
                       dtype),
        "v": jnp.zeros((count, num_blocks, block_size, kv_heads, head_dim),
                       dtype),
        "pos": jnp.full((batch, L), -1, jnp.int32),
        "btab": jnp.full((batch, M), -1, jnp.int32),
    }


def cache_len_for(window: int, max_len: int) -> int:
    from repro.configs.base import GLOBAL_WINDOW
    if window >= GLOBAL_WINDOW or window <= 0:
        return max_len
    return min(window, max_len)


# --- recurrent-state entries (xLSTM / Mamba-style) -------------------------

def mlstm_entry(count, batch, heads, dh, dtype=jnp.float32):
    return {
        "C": jnp.zeros((count, batch, heads, dh, dh), dtype),
        "n": jnp.zeros((count, batch, heads, dh), dtype),
        "m": jnp.full((count, batch, heads), -jnp.inf, dtype),
    }


def slstm_entry(count, batch, heads, dh, dtype=jnp.float32):
    return {
        "c": jnp.zeros((count, batch, heads, dh), dtype),
        "n": jnp.zeros((count, batch, heads, dh), dtype),
        "h": jnp.zeros((count, batch, heads, dh), dtype),
        "m": jnp.full((count, batch, heads, dh), -jnp.inf, dtype),
    }


def ssm_entry(count, batch, d_inner, state, conv_taps=3, dtype=jnp.float32):
    return {
        "h": jnp.zeros((count, batch, d_inner, state), dtype),
        "conv": jnp.zeros((count, batch, conv_taps, d_inner), dtype),
    }
