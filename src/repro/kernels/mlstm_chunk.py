"""Pallas TPU chunkwise-parallel mLSTM.

The xLSTM matrix-memory recurrence has a chunkwise form: an intra-chunk
attention-like term (L x L matmuls — MXU work) plus an inter-chunk state
(C: dh x dh, n: dh, m: scalar) carried sequentially.  The XLA path (see
repro/models/ssm.py) scans chunks at HLO level, re-loading state from HBM
each step; this kernel keeps the carry in VMEM scratch across the
sequential grid dimension and fuses the decay/gate elementwise math into
the two MXU matmuls per chunk.

Grid: (B, H, n_chunks) with n_chunks 'arbitrary' (sequential).  The
chunk-local cumulative log-forget ``bc`` is precomputed outside (cheap,
XLA) so the kernel body is pure matmul + elementwise.

Outputs: hidden states (B, H, S, dh) and the final (C, n, m) state for
decode continuation.  Oracle: repro/kernels/ref.py::mlstm_chunk_ref via
the model-layer chunk function (itself tested against the sequential
recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions;
# fail at import (AttributeError names the missing symbol) if neither exists
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, bc_ref, li_ref, h_ref, c_out_ref,
            n_out_ref, m_out_ref, c_scr, n_scr, m_scr, *,
            L: int, dh: int, n_chunks: int, scale: float):
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, NEG)

    q = q_ref[...].reshape(L, dh).astype(jnp.float32)
    k = k_ref[...].reshape(L, dh).astype(jnp.float32)
    v = v_ref[...].reshape(L, dh).astype(jnp.float32)
    b = bc_ref[...].reshape(L, 1)                  # chunk-local cum log f
    li = li_ref[...].reshape(L, 1)
    C_in = c_scr[...]
    n_in = n_scr[...]                              # (1, dh)
    m_in = m_scr[0, 0]

    # intra-chunk decay scores g[t,s] = b_t - b_s + li_s, s <= t
    g = b - b.reshape(1, L) + li.reshape(1, L)
    ti = lax.broadcasted_iota(jnp.int32, (L, L), 0)
    si = lax.broadcasted_iota(jnp.int32, (L, L), 1)
    g = jnp.where(ti >= si, g, NEG)
    m_intra = jnp.max(g, axis=1, keepdims=True)    # (L,1)
    m_t = jnp.maximum(m_in + b, m_intra)
    s = jnp.exp(g - m_t)
    qk = lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                         preferred_element_type=jnp.float32) * scale
    w = qk * s
    inter = jnp.exp(m_in + b - m_t) * scale        # (L,1)
    num = lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32) \
        + lax.dot_general(q * inter, C_in, (((1,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32)
    den = jnp.sum(w, axis=1, keepdims=True) \
        + lax.dot_general(q * inter, n_in.reshape(dh, 1),
                          (((1,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    h_ref[...] = h.reshape(h_ref.shape).astype(h_ref.dtype)

    # state update
    bL = b[L - 1, 0]
    dec = bL - b + li                               # (L,1)
    m_out = jnp.maximum(m_in + bL, jnp.max(dec))
    carry = jnp.exp(m_in + bL - m_out)
    kvc = jnp.exp(dec - m_out)                      # (L,1)
    C_out = C_in * carry + lax.dot_general(
        k * kvc, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_out = n_in * carry + jnp.sum(k * kvc, axis=0, keepdims=True)
    c_scr[...] = C_out
    n_scr[...] = n_out
    m_scr[...] = jnp.full_like(m_scr, m_out)

    @pl.when(cb == n_chunks - 1)
    def _emit_state():
        c_out_ref[...] = C_out.reshape(c_out_ref.shape)
        n_out_ref[...] = n_out.reshape(n_out_ref.shape)
        m_out_ref[...] = jnp.full(m_out_ref.shape, m_out, jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk(q, k, v, li, lf, *, chunk: int = 128,
                interpret: bool = False):
    """q/k/v: (B, H, S, dh) ; li/lf: (B, H, S) log gates.
    Returns (h (B,H,S,dh) f32, (C (B,H,dh,dh), n (B,H,dh), m (B,H)))."""
    B, H, S, dh = q.shape
    L = min(chunk, S)
    if S % L:
        L = S
    n_chunks = S // L
    # chunk-local cumulative log-forget, precomputed in XLA
    bc = jnp.cumsum(lf.reshape(B, H, n_chunks, L), axis=-1) \
        .reshape(B, H, S, 1)
    li4 = li.reshape(B, H, S, 1)
    kernel = functools.partial(_kernel, L=L, dh=dh, n_chunks=n_chunks,
                               scale=dh ** -0.5)
    h, C, n, m = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, L, dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, L, 1), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, L, dh), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, dh), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dh, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, 1, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, 1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dh, dh), jnp.float32),
            pltpu.VMEM((1, dh), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, bc, li4)
    return h, (C, n.reshape(B, H, dh), m.reshape(B, H))
