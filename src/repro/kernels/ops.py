"""jit'd public wrappers around the Pallas kernels.

``attention`` dispatches to the flash kernel on TPU (or when forced via
``use_kernel=True``, e.g. interpret-mode tests) and to the pure-jnp
reference otherwise — the dry-run on the CPU backend lowers the XLA
path, the kernel is the TPU deployment path (see DESIGN.md §2).
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_chunk import mlstm_chunk


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              use_kernel: bool = False, interpret: bool = False,
              block_q: int = 128, block_k: int = 128):
    if use_kernel or on_tpu():
        return flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret or not on_tpu())
    return ref.attention_ref(q, k, v, causal=causal, window=window)
