"""Pure-jnp oracles for every kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Exact attention.  q: (B, Sq, Hq, dh); k/v: (B, Sk, Hkv, dh)."""
    B, Sq, Hq, dh = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, g, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, kf) * dh ** -0.5
    if causal:
        qi = jnp.arange(Sq)[:, None]
        ki = jnp.arange(Sk)[None, :]
        rel = qi - ki
        mask = rel >= 0
        if window > 0:
            mask &= rel < window
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqs,bshd->bqhgd", p, vf)
    return o.reshape(B, Sq, Hq, dh).astype(q.dtype)


def mlstm_chunk_ref(q, k, v, li, lf, state):
    """Chunkwise mLSTM oracle — re-exports the model-layer implementation
    (which is itself validated against the L=1 sequential recurrence)."""
    from repro.models.ssm import _mlstm_chunk
    return _mlstm_chunk(q, k, v, li, lf, state)
