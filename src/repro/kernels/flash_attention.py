"""Pallas TPU flash attention (causal / sliding-window / GQA).

Why a kernel here: the XLA attention path materializes (Tq, Tk) logits in
fp32 — the dominant memory-roofline term for every train/prefill cell
(see EXPERIMENTS.md §Roofline) — and cannot skip fully-masked key blocks,
so sliding-window archs (danube, gemma locals, hymba) pay full quadratic
traffic.  The kernel keeps the online-softmax state in VMEM, streams KV
blocks through VMEM tiles, and skips key blocks that the causal/window
mask kills entirely: O(S*W) instead of O(S^2) for windowed layers.

TPU mapping: grid = (batch, q_heads, q_blocks, kv_blocks) with the
kv_blocks dimension 'arbitrary' (sequential) so the (m, l, acc) online
state lives in VMEM scratch across kv iterations; MXU-aligned tiles
(block sizes multiples of 128 on the lane dim); fp32 accumulation.

Validated against ref.py (pure jnp) in interpret mode on CPU — the
container has no TPU; `interpret=True` executes the same kernel body.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams across jax versions;
# fail at import (AttributeError names the missing symbol) if neither exists
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, n_kv: int, causal: bool, window: int,
            scale: float):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qb * bq
    k_start = kb * bk
    # block-level skip: any (q, k) pair alive in this tile?
    # causal: need k_start <= q_end;  window: need k_end >= q_start-window+1
    q_end = q_start + bq - 1
    k_end = k_start + bk - 1
    alive = jnp.asarray(True)
    if causal:
        alive = k_start <= q_end
        if window > 0:
            alive = jnp.logical_and(alive, k_end >= q_start - window + 1)

    @pl.when(alive)
    def _body():
        dh = q_ref.shape[-1]
        q = q_ref[...].reshape(bq, dh).astype(jnp.float32)
        k = k_ref[...].reshape(bk, dh).astype(jnp.float32)
        v = v_ref[...].reshape(bk, dh).astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)
        q_idx = q_start + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_idx = k_start + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            rel = q_idx - k_idx
            mask = rel >= 0
            if window > 0:
                mask = jnp.logical_and(mask, rel < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                          # (bq, 1)
        m_cur = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=1))[:, None]
        alpha = jnp.exp(m_prev - m_cur)              # (bq, 1)
        p = jnp.exp(s - m_cur)                       # (bq, bk)
        l_cur = l_scr[...] * alpha + jnp.sum(p, axis=1)[:, None]
        acc = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_cur
        l_scr[...] = l_cur
        acc_scr[...] = acc

    @pl.when(kb == n_kv - 1)
    def _finish():
        l = l_scr[...]
        o = acc_scr[...] / jnp.maximum(l, 1e-30)
        o_ref[...] = o.reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, Hq, dh); k/v: (B, Sk, Hkv, dh); GQA via Hq % Hkv == 0.
    window=0 means unbounded (full causal); window=w keeps k in
    (q-w, q].  Returns (B, Sq, Hq, dh) in q.dtype."""
    B, Sq, Hq, dh = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    g = Hq // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    n_q, n_kv = Sq // bq, Sk // bk
    scale = dh ** -0.5

    # (B, H, S, dh) layout for clean 2-D tiles
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, bq=bq, bk=bk, n_kv=n_kv,
                               causal=causal, window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, qb, kb: (b, h, qb, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, qb, kb, g=g: (b, h // g, kb, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, qb, kb, g=g: (b, h // g, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b, h, qb, kb: (b, h, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
