"""Sample collection: warmup-discarded wall-clock timings of jitted
calls, summarized as median/p95/min (us).  The old ``time_fn`` median
in ``benchmarks/common.py`` is a shim over this module."""
from __future__ import annotations

import math
import time
from typing import Callable, Dict, List, Sequence


def sample(fn: Callable, *args, warmup: int = 2, iters: int = 5
           ) -> List[float]:
    """Wall-clock seconds per call, warmup calls discarded.  Blocks on
    the result each iteration so async dispatch doesn't hide the work."""
    import jax

    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn(*args))
    out: List[float] = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        out.append(time.perf_counter() - t0)
    return out


def sample_paired(fn_a, args_a, fn_b, args_b, *, warmup: int = 2,
                  iters: int = 5):
    """Interleaved A/B timing: alternate single calls of ``a`` and ``b``
    so slow host drift (thermal, co-tenant load) biases both samples
    equally — best-of-N differences stay meaningful where back-to-back
    blocks would not.  Returns ``(samples_a, samples_b)`` in seconds."""
    import jax

    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn_a(*args_a))
        jax.block_until_ready(fn_b(*args_b))
    sa: List[float] = []
    sb: List[float] = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args_a))
        sa.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args_b))
        sb.append(time.perf_counter() - t0)
    return sa, sb


def _quantile(sorted_s: Sequence[float], q: float) -> float:
    """Nearest-rank quantile of an already-sorted sample."""
    idx = min(len(sorted_s) - 1, max(0, math.ceil(q * len(sorted_s)) - 1))
    return sorted_s[idx]


def stats_us(samples: Sequence[float]) -> Dict[str, float]:
    """median/p95/min in microseconds from per-call seconds."""
    s = sorted(samples)
    return {
        "median_us": _quantile(s, 0.5) * 1e6,
        "p95_us": _quantile(s, 0.95) * 1e6,
        "min_us": s[0] * 1e6,
    }


def gbps(size_bytes: float, us: float) -> float:
    """Derived bandwidth for a transfer of ``size_bytes`` in ``us``."""
    return size_bytes / (max(us, 1e-9) * 1e-6) / 1e9
