"""repro.bench — the paper's benchmark matrix as a first-class subsystem.

The source paper is a *performance study*: its contribution is the
Figs 2-7 sweeps comparing pPython's messaging against mpi4py.  This
package makes that sweep declarative, reproducible, and enforceable:

  * :mod:`repro.bench.registry` — each paper figure/table is a
    :class:`BenchCase` (name, device count, figure, implementation);
    size/rank/iteration budgets come from a named :class:`Profile`.
  * :mod:`repro.bench.cases`    — the case implementations, driving the
    public :class:`~repro.comms.Communicator` surface only (the OMB-Py
    discipline: benchmark what users call, not private internals).
  * :mod:`repro.bench.runner`   — executes cases in per-device-count
    subprocesses (the parent never re-initializes jax), collects
    warmup-discarded samples, reports median/p95/min + derived GB/s.
  * :mod:`repro.bench.results`  — schema-versioned ``BENCH_*.json``
    writer (git sha, jax version, device counts, per-case rows) plus
    the legacy ``name,us_per_call,derived`` CSV on stdout.
  * :mod:`repro.bench.compare`  — diffs a run against a committed
    ``benchmarks/baseline.json`` and exits non-zero on relative
    slowdown past a noise-tolerant threshold (the CI regression gate).

Entry points: ``python -m repro.bench`` (or the ``repro-bench`` console
script) to run; ``python -m repro.bench.compare RUN BASELINE`` to gate.
This module imports no jax — only case implementations do, inside the
subprocess that owns the right virtual-device count.
"""
from repro.bench.registry import (BenchCase, Profile, PROFILES, all_cases,
                                  get_case, register_case)

__all__ = ["BenchCase", "Profile", "PROFILES", "all_cases", "get_case",
           "register_case"]
