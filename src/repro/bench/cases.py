"""The paper's benchmark matrix as registered cases.

    p2p            Fig 2/3   send/roundtrip size sweep + v5e link model
    multipair      OMB-Py    k simultaneous p2p pairs, aggregate GB/s
    bibw           OMB-Py    bidirectional sendrecv bandwidth
    msgrate        OMB-Py    back-to-back small-message issue rate
    overlap        Charm4Py  overlap fraction: compute + in-flight
                             allreduce vs the sum of each alone
    agg            Fig 5     tree vs native aggregation, 2..8 ranks
    bcast          Fig 7     serial/tree/native broadcast + pod-scale model
    scatter        Fig 6     scatter (per-transport bcast schedule) and
                             gather-to-nonzero-root, tree vs native
    grad_exchange  trainer   allreduce variants on the 2x2x2 pod mesh
                             with HLO link-byte accounting, plus the
                             train-step tie-in (blocking vs overlap
                             microbatch pipeline, steps.py)
    stream         HPCC      STREAM triad local-bandwidth anchor

Every measured case drives the public :class:`~repro.comms.Communicator`
surface only (OMB-Py discipline; the OMB-Py/Charm4Py-parity families
mirror arXiv:2110.10659 / arXiv:2111.04872).  jax is imported inside the
bodies: this module's *metadata* must be importable in the parent
process before any device initialization.
"""
from __future__ import annotations

from repro.bench import hw
from repro.bench.registry import BenchContext, register_case
from repro.bench.sampling import gbps


def _comm_op_fn(comm, op, spec, **kw):
    """jit a single collective through ``comm.wrap``, reducing the output
    to one tiny value per rank so timing isn't dominated by materializing
    the gathered buffer."""
    import jax

    def body(a):
        out = getattr(comm, op)(a, **kw)
        return out.reshape(1, -1).mean(1, keepdims=True)
    return jax.jit(comm.wrap(body, in_specs=(spec,), out_specs=spec))


# ------------------------------------------------------------------ p2p


@register_case("p2p", figure="fig2/3", ndev=2,
               description="point-to-point send/roundtrip size sweep "
                           "over Communicator send/recv")
def run_p2p(ctx: BenchContext):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comms import Communicator

    mesh = jax.make_mesh((2,), ("x",))
    comm = Communicator(mesh)
    spec = P("x")

    def oneway(v):
        return comm.send(v, dst=1, src=0)

    def roundtrip(v):
        return comm.recv(comm.send(v, dst=1, src=0), 1, dst=0)

    for size in ctx.profile.p2p_sizes:
        n = max(size // 4, 1)
        x = jnp.zeros((2, n), jnp.float32)
        f = jax.jit(comm.wrap(oneway, in_specs=(spec,), out_specs=spec))
        g = jax.jit(comm.wrap(roundtrip, in_specs=(spec,), out_specs=spec))
        st = ctx.measure(f, x)
        yield ctx.row(f"p2p_send_{size}B", ranks=2, size_bytes=size,
                      stats=st, gbps=gbps(size, st["median_us"]))
        yield ctx.row(f"p2p_roundtrip_{size}B", ranks=2, size_bytes=size,
                      stats=ctx.measure(g, x))

    if not ctx.profile.modeled:
        return
    for size in ctx.profile.p2p_sizes:
        t_ici = hw.ICI_LAT + size / hw.ICI_BW
        t_dci = hw.DCI_LAT + size / hw.DCI_BW
        yield ctx.model_row(f"p2p_model_ici_{size}B", us=t_ici * 1e6,
                            ranks=2, size_bytes=size,
                            gbps=size / t_ici / 1e9)
        yield ctx.model_row(f"p2p_model_dci_{size}B", us=t_dci * 1e6,
                            ranks=2, size_bytes=size,
                            gbps=size / t_dci / 1e9)


# ------------------------------------- OMB-Py parity: multipair / bibw /
# msgrate (arXiv:2110.10659 §4: multi-pair bandwidth, bidirectional
# bandwidth, message rate — dimensions the paper's Fig 2/3 single-pair
# sweep does not cover)


@register_case("multipair", figure="omb:multipair", ndev=8,
               description="k simultaneous disjoint p2p pairs in one "
                           "sendrecv round; aggregate GB/s")
def run_multipair(ctx: BenchContext):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comms import Communicator

    n = max(ctx.ndev - ctx.ndev % 2, 2)
    mesh = jax.make_mesh((n,), ("x",))
    comm = Communicator(mesh)
    spec = P("x")
    for k in sorted({1, 2, n // 2}):
        if k > n // 2:
            continue
        pairs = tuple((2 * i, 2 * i + 1) for i in range(k))
        for size in ctx.profile.p2p_sizes:
            x = jnp.zeros((n, max(size // 4, 1)), jnp.float32)

            def body(v, ps=pairs):
                out = comm.sendrecv(v, ps)
                return out.reshape(1, -1).mean(1, keepdims=True)
            f = jax.jit(comm.wrap(body, in_specs=(spec,), out_specs=spec))
            st = ctx.measure(f, x)
            yield ctx.row(f"multipair_k{k}_{size}B", ranks=n,
                          size_bytes=size, stats=st,
                          gbps=gbps(size * k, st["median_us"]),
                          note=f"pairs={k} aggregate")


@register_case("bibw", figure="omb:bibw", ndev=2,
               description="bidirectional bandwidth: both directions of "
                           "one pair in flight in the same round")
def run_bibw(ctx: BenchContext):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comms import Communicator

    mesh = jax.make_mesh((2,), ("x",))
    comm = Communicator(mesh)
    spec = P("x")

    def body(v):
        out = comm.sendrecv(v, ((0, 1), (1, 0)))
        return out.reshape(1, -1).mean(1, keepdims=True)

    f = jax.jit(comm.wrap(body, in_specs=(spec,), out_specs=spec))
    for size in ctx.profile.p2p_sizes:
        x = jnp.zeros((2, max(size // 4, 1)), jnp.float32)
        st = ctx.measure(f, x)
        yield ctx.row(f"bibw_{size}B", ranks=2, size_bytes=size, stats=st,
                      gbps=gbps(2 * size, st["median_us"]),
                      note="2x payload in flight")


@register_case("msgrate", figure="omb:msgrate", ndev=2,
               description="back-to-back small-message issue rate: a "
                           "chained window of sends per timed call")
def run_msgrate(ctx: BenchContext):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comms import Communicator

    mesh = jax.make_mesh((2,), ("x",))
    comm = Communicator(mesh)
    spec = P("x")
    window = ctx.profile.msgrate_window
    size = ctx.profile.p2p_sizes[0]

    def body(v):
        # chained (+1 defeats CSE): each hop issues only after the
        # previous returns — OMB-Py's back-to-back message discipline
        for _ in range(window):
            v = comm.send(v + 1.0, dst=1, src=0)
        return v.reshape(1, -1).mean(1, keepdims=True)

    f = jax.jit(comm.wrap(body, in_specs=(spec,), out_specs=spec))
    x = jnp.zeros((2, max(size // 4, 1)), jnp.float32)
    st = ctx.measure(f, x)
    rate = window / (st["min_us"] * 1e-6)
    yield ctx.row(f"msgrate_w{window}_{size}B", ranks=2, size_bytes=size,
                  stats=st, note=f"msgs/s={rate:.0f} window={window}")


# ------------------------------------------- Charm4Py parity: overlap


@register_case("overlap", figure="charm4py:overlap", ndev=2,
               description="overlap fraction per transport/size: an "
                           "R-slot compute+allreduce pipeline, blocking "
                           "vs double-buffered in one program")
def run_overlap(ctx: BenchContext):
    """Charm4Py's headline measurement (arXiv:2111.04872 §5.3): how much
    collective time hides behind compute when the exchange is issued a
    slot early.  Two jitted programs, each R = ``overlap_slots`` slots of
    (matmul-chain compute, allreduce):

      * ``blocking``   — slot i's allreduce operand depends on slot i's
        compute output, so every exchange serializes after its compute;
      * ``overlapped`` — the pipeline is double-buffered: slot i
        exchanges the payload produced by slot i-1, which is ready at
        slot entry, so XLA may schedule the collective alongside the
        matmuls (rendezvous/dispatch hiding even without spare cores).

    Same compute, same R collectives of the same size; the fraction

        frac = (t_blocking - t_overlapped) / t_coll_only

    (best-of-N, t_coll_only = R chained allreduces alone) is the share
    of total collective time the restructuring recovers: 0 = none,
    1 = fully hidden.  This is the microbenchmark form of the train
    step's ``*_overlap`` grad-exchange pipeline (train/steps.py), and
    the R-slot repetition keeps the timed region in the multi-ms range
    where best-of-N is stable on an oversubscribed host.  Pair scale
    (ndev=2) on purpose: overlap is a per-link property, and more
    virtual ranks on one host only add rendezvous jitter."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comms import Communicator

    n = ctx.ndev
    mesh = jax.make_mesh((n,), ("x",))
    spec = P("x")
    d = ctx.profile.overlap_compute_dim
    reps = ctx.profile.overlap_compute_iters
    slots = max(ctx.profile.overlap_slots, 2)

    def chain(z, w):
        for _ in range(reps):
            z = jnp.tanh(z @ w)
        return z

    z0 = jnp.ones((n, d, d), jnp.float32)
    w0 = jnp.ones((d, d), jnp.float32) * 0.01
    sizes = sorted(set(ctx.profile.overlap_sizes))
    for tname in ("native", "tree", "hier"):
        comm = Communicator(mesh, tname)

        def coll_only(v):
            # R chained exchanges (+1 defeats CSE): total collective time
            for _ in range(slots):
                v = comm.allreduce(v + 1.0) / n
            return v.reshape(1, -1).mean(1, keepdims=True)

        def blocking(v, z, w):
            # slot i's payload derives from slot i's compute: the
            # exchange cannot start until the matmul chain retires
            acc = jnp.zeros((1, 1), jnp.float32)
            for _ in range(slots):
                z = chain(z, w)
                payload = v + z[0, :1, :1]
                acc = acc + comm.allreduce(payload).mean()
            return acc / slots

        def overlapped(v, z, w):
            # double-buffered: slot i exchanges slot i-1's payload,
            # ready at slot entry — same compute, same R collectives
            acc = jnp.zeros((1, 1), jnp.float32)
            z = chain(z, w)
            pending = v + z[0, :1, :1]
            for _ in range(slots - 1):
                acc = acc + comm.allreduce(pending).mean()
                z = chain(z, w)
                pending = v + z[0, :1, :1]
            acc = acc + comm.allreduce(pending).mean()   # drain
            return acc / slots

        for size in sizes:
            x = jnp.ones((n, max(size // 4, 1)), jnp.float32)
            f_coll = jax.jit(comm.wrap(coll_only, in_specs=(spec,),
                                       out_specs=spec))
            f_blk = jax.jit(comm.wrap(blocking, in_specs=(spec, spec, P()),
                                      out_specs=P()))
            f_ovl = jax.jit(comm.wrap(overlapped,
                                      in_specs=(spec, spec, P()),
                                      out_specs=P()))
            from repro.bench.sampling import sample_paired, stats_us
            st_coll = ctx.measure(f_coll, x)
            # interleave blocking/overlapped samples so host drift hits
            # both equally and the best-of-N difference stays meaningful
            s_blk, s_ovl = sample_paired(
                f_blk, (x, z0, w0), f_ovl, (x, z0, w0),
                warmup=ctx.profile.warmup, iters=ctx.profile.iters)
            st_blk, st_ovl = stats_us(s_blk), stats_us(s_ovl)
            frac = ((st_blk["min_us"] - st_ovl["min_us"])
                    / max(st_coll["min_us"], 1e-9))
            yield ctx.row(
                f"overlap_{tname}_{size}B", transport=tname, ranks=n,
                size_bytes=size, stats=st_ovl,
                note=f"frac={frac:.3f} blocking_us={st_blk['min_us']:.0f} "
                     f"coll_us={st_coll['min_us']:.0f} slots={slots}")


# ----------------------------------------------------------- agg / bcast


def _rank_sweep(ctx: BenchContext):
    """(mesh, comms, spec, n) per rank count, transports shared."""
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.comms import Communicator

    for n in ctx.rank_counts():
        mesh = jax.make_mesh((n,), ("r",))
        comms = {name: Communicator(mesh, name)
                 for name in ("native", "tree", "serial")}
        yield n, comms, P("r")


def _per_rank_input(n: int, size: int):
    import jax.numpy as jnp
    return jnp.ones((n, max(size // 4, 1)), jnp.float32)


@register_case("agg", figure="fig5", ndev=8,
               description="aggregation: paper tree gather vs native "
                           "all-gather, 2..8 ranks x per-rank sizes")
def run_agg(ctx: BenchContext):
    for n, comms, spec in _rank_sweep(ctx):
        for size in ctx.profile.coll_sizes:
            x = _per_rank_input(n, size)
            for tname in ("tree", "native"):
                st = ctx.measure(_comm_op_fn(comms[tname], "agg", spec), x)
                yield ctx.row(f"agg_{tname}_r{n}_{size}B", transport=tname,
                              ranks=n, size_bytes=size, stats=st)


@register_case("bcast", figure="fig7", ndev=8,
               description="broadcast: serial (paper initial) vs tree "
                           "(optimized) vs native, plus pod-scale model")
def run_bcast(ctx: BenchContext):
    for n, comms, spec in _rank_sweep(ctx):
        for size in ctx.profile.coll_sizes:
            x = _per_rank_input(n, size)
            for tname in ("tree", "serial", "native"):
                st = ctx.measure(_comm_op_fn(comms[tname], "bcast", spec), x)
                yield ctx.row(f"bcast_{tname}_r{n}_{size}B",
                              transport=tname, ranks=n, size_bytes=size,
                              stats=st)

    if not ctx.profile.modeled:
        return
    # Fig 7 extension: two-level model at pod scale (in-pod 256 ranks on
    # ICI, cross-pod on DCI)
    from repro.core import topology

    for total in (64, 256, 512, 768):
        n_local = min(total, 256)
        n_global = max(total // 256, 1)
        for size in ctx.profile.coll_sizes:
            t_tree = topology.two_level_cost(n_local, n_global, size,
                                             hw.ICI_BW, hw.DCI_BW,
                                             tree=True)
            t_serial = topology.two_level_cost(n_local, n_global, size,
                                               hw.ICI_BW, hw.DCI_BW,
                                               tree=False)
            yield ctx.model_row(
                f"bcast_model_tree_r{total}_{size}B", us=t_tree * 1e6,
                transport="tree", ranks=total, size_bytes=size,
                note=f"speedup={t_serial / max(t_tree, 1e-12):.1f}x")
            yield ctx.model_row(
                f"bcast_model_serial_r{total}_{size}B", us=t_serial * 1e6,
                transport="serial", ranks=total, size_bytes=size)


# ------------------------------------------------------ scatter / gather


@register_case("scatter", figure="fig6", ndev=8,
               description="scatter (root distributes blocks; schedule "
                           "follows the transport's bcast) and gather to "
                           "a non-zero root")
def run_scatter(ctx: BenchContext):
    for n, comms, spec in _rank_sweep(ctx):
        for size in ctx.profile.coll_sizes:
            x = _per_rank_input(n, size)
            for tname in ("tree", "serial", "native"):
                st = ctx.measure(
                    _comm_op_fn(comms[tname], "scatter", spec), x)
                yield ctx.row(f"scatter_{tname}_r{n}_{size}B",
                              transport=tname, ranks=n, size_bytes=size,
                              stats=st)
            # gather-to-root at the far end of the rank line (root=n-1):
            # exercises the rotated tree schedule, the Fig 6 direction
            # the agg case (root=0) does not cover
            for tname in ("tree", "native"):
                st = ctx.measure(
                    _comm_op_fn(comms[tname], "agg", spec, root=n - 1), x)
                yield ctx.row(f"gather_root{n - 1}_{tname}_r{n}_{size}B",
                              transport=tname, ranks=n, size_bytes=size,
                              stats=st)


# ---------------------------------------------------- alltoall / MoE


@register_case("alltoall", figure="fig3+moe", ndev=8,
               description="all-to-all message-size sweep across "
                           "transports, ragged alltoallv, and "
                           "expert-parallel MoE dispatch tokens/sec")
def run_alltoall(ctx: BenchContext):
    import jax
    import jax.numpy as jnp

    # --- message-size sweep (the Fig 2/3 discipline applied to the
    # routed-exchange collective OMB-Py benchmarks as a core family)
    for n, comms, spec in _rank_sweep(ctx):
        for size in ctx.profile.coll_sizes:
            elems = max(size // 4, n)
            elems -= elems % n
            x = jnp.ones((n, elems), jnp.float32)
            for tname in ("native", "tree", "serial"):
                comm = comms[tname]

                def body(a, c=comm, nn=n):
                    out = c.alltoall(a.reshape(nn, -1))
                    return out.reshape(1, -1).mean(1, keepdims=True)
                f = jax.jit(comm.wrap(body, in_specs=(spec,),
                                      out_specs=spec))
                st = ctx.measure(f, x)
                yield ctx.row(f"alltoall_{tname}_r{n}_{size}B",
                              transport=tname, ranks=n, size_bytes=size,
                              stats=st,
                              gbps=gbps(size, st["median_us"]))
        # ragged exchange: one alltoallv row per rank count at the
        # mid-profile size, asymmetric static count matrix
        size = ctx.profile.coll_sizes[len(ctx.profile.coll_sizes) // 2]
        base = max(size // 4 // n, 1)
        counts = [[base * ((i + 2 * j) % 3 + 1) for j in range(n)]
                  for i in range(n)]
        S = max(sum(r) for r in counts)
        xv = jnp.ones((n, S), jnp.float32)
        for tname in ("native", "tree"):
            comm = comms[tname]

            def bodyv(a, c=comm, cnt=counts, s=S):
                out = c.alltoallv(a.reshape(s, 1), cnt)
                return out.reshape(1, -1).mean(1, keepdims=True)
            f = jax.jit(comm.wrap(bodyv, in_specs=(spec,),
                                  out_specs=spec))
            st = ctx.measure(f, xv)
            yield ctx.row(f"alltoallv_{tname}_r{n}_{size}B",
                          transport=tname, ranks=n, size_bytes=size,
                          stats=st)

    # --- MoE expert-parallel dispatch at model scale: two alltoalls
    # (dispatch + combine) per step through the same Communicator
    from repro.models.moe import moe_ffn, moe_init

    pr = ctx.profile
    m = 1 << (ctx.ndev.bit_length() - 1)        # model-axis power of two
    mesh = jax.make_mesh((1, m), ("data", "model"))
    E = max(pr.moe_experts // m, 1) * m
    T = max(pr.moe_tokens // m, 1) * m
    key = jax.random.PRNGKey(0)
    params = moe_init(key, pr.moe_d_model, pr.moe_d_ff, E)
    x = jax.random.normal(key, (1, T, pr.moe_d_model), jnp.bfloat16)
    for tname in ("native", "tree"):
        f = jax.jit(lambda p, v, t=tname: moe_ffn(
            p, v, top_k=pr.moe_top_k, num_experts=E,
            capacity_factor=2.0, mesh=mesh, batch_axes=("data",),
            mode="scatter", comm=t)[0])
        st = ctx.measure(f, params, x)
        toks = T / (st["median_us"] * 1e-6)
        yield ctx.row(f"moe_dispatch_{tname}_t{T}", transport=tname,
                      ranks=m, size_bytes=T * pr.moe_d_model * 2,
                      stats=st, note=f"tok/s={toks:.0f}")


# -------------------------------------------------------- grad exchange


@register_case("grad_exchange", figure="trainer", ndev=8,
               description="gradient allreduce variants on the pod mesh "
                           "with HLO link-byte accounting, plus the "
                           "blocking-vs-overlap train-step tie-in")
def run_grad_exchange(ctx: BenchContext):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comms import CommSpec, Communicator
    from repro.roofline import hlo as hlo_lib

    if ctx.ndev >= 8:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        axes, pod_size, n_pods = ("pod", "data"), 4, 2
    else:  # tiny/test budget: batch-axis-only exchange, no pod level
        mesh = jax.make_mesh((ctx.ndev,), ("data",))
        axes, pod_size, n_pods = ("data",), ctx.ndev, 1
    ranks = ctx.ndev if ctx.ndev < 8 else 8
    nbytes = ctx.profile.gradex_bytes
    x = jnp.ones((ranks, max(nbytes // 4 // ranks, 1)), jnp.float32)
    spec = P(tuple(mesh.axis_names))

    for name in ("native", "tree", "hier", "hier_int8"):
        comm = Communicator(mesh, CommSpec.from_flag(name), axes=axes)
        f = jax.jit(comm.wrap(comm.allreduce, in_specs=(spec,),
                              out_specs=spec))
        st = ctx.measure(f, x)
        an = hlo_lib.analyze(f.lower(x).compile().as_text(),
                             pod_size=pod_size, n_pods=n_pods)
        yield ctx.row(
            f"gradex_{name}_{nbytes}B", transport=name, ranks=ranks,
            size_bytes=nbytes, stats=st,
            note=f"link={an.get('link_bytes', 0.0) / 2 ** 20:.2f}MiB "
                 f"dci={an.get('dci_link_bytes', 0.0) / 2 ** 20:.2f}MiB")

    # --- train-step tie-in: the same exchange inside the real
    # microbatched step (train/steps.py), blocking scan vs the
    # one-slot-deep overlap pipeline — the row pair the `overlap`
    # microbenchmark case predicts
    from jax.sharding import NamedSharding

    from repro.configs.base import ShapeSpec, get_config, reduced
    from repro.launch.mesh import make_local_mesh
    from repro.models.model import Model
    from repro.optim.optimizer import OptimizerConfig, opt_init
    from repro.train import steps as steps_lib

    pr = ctx.profile
    cfg = reduced(get_config("h2o-danube-1.8b"),
                  microbatches=pr.gradex_step_mb)
    shape = ShapeSpec("bench", "train", pr.gradex_step_seq,
                      pr.gradex_step_batch)
    tmesh = (make_local_mesh(2, 2, pod=2) if ctx.ndev >= 8
             else make_local_mesh(ctx.ndev, 1))
    model = Model(cfg, tmesh)
    ocfg = OptimizerConfig()
    bundle = steps_lib.sharding_bundle(model, ocfg, shape)
    params = jax.jit(model.init,
                     out_shardings=bundle["params"])(jax.random.PRNGKey(0))
    opt = jax.jit(lambda p: opt_init(ocfg, p),
                  out_shardings=bundle["opt"])(params)
    toks = jax.random.randint(
        jax.random.PRNGKey(1),
        (pr.gradex_step_batch, pr.gradex_step_seq), 0, cfg.vocab_size)
    batch = jax.device_put({"tokens": toks, "labels": toks},
                           bundle["input_shardings"])
    step0 = jnp.zeros((), jnp.int32)
    gbytes = 4 * sum(p.size for p in jax.tree.leaves(params))
    for mode in ("tree", "tree_overlap"):
        step_fn, mbn = steps_lib.make_train_step(
            model, ocfg, shape.global_batch, grad_comms=mode)
        f = jax.jit(step_fn,
                    in_shardings=(bundle["params"], bundle["opt"],
                                  bundle["input_shardings"],
                                  NamedSharding(tmesh, P())),
                    out_shardings=(bundle["params"], bundle["opt"], None))
        st = ctx.measure(f, params, opt, batch, step0)
        label = "overlap" if mode.endswith("_overlap") else "blocking"
        yield ctx.row(f"gradex_step_{label}_tree", transport="tree",
                      ranks=ctx.ndev, size_bytes=gbytes, stats=st,
                      note=f"mb={mbn} batch={pr.gradex_step_batch} "
                           f"seq={pr.gradex_step_seq}")


# --------------------------------------------------------- compression


@register_case("compression", figure="fig3", ndev=8,
               description="wire vs effective GB/s for the compressed "
                           "allreduce: each quantization dtype composed "
                           "with the tree/hier transports")
def run_compression(ctx: BenchContext):
    import dataclasses

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comms import CommSpec, Communicator, CompressionSpec

    if ctx.ndev >= 8:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        axes = ("pod", "data")
    else:  # tiny/test budget: batch-axis-only exchange, no pod level
        mesh = jax.make_mesh((ctx.ndev,), ("data",))
        axes = ("data",)
    ranks = ctx.ndev if ctx.ndev < 8 else 8
    spec = P(tuple(mesh.axis_names))
    # cross-pod dominates a hierarchical exchange, so scope the wire
    # quantization there — exactly what `--grad-comms tree_int8` runs
    for size in ctx.profile.compress_sizes:
        n = max(size // 4 // ranks, 1)          # f32 elements per rank
        x = jnp.ones((ranks, n), jnp.float32)
        logical = 4 * n                          # per-rank payload, bytes
        for tname in ("tree", "hier"):
            base = CommSpec.from_flag(tname)
            for dtype in (None, "int8", "fp8", "int4"):
                if dtype is None:
                    cs, cspec, label = base, None, "none"
                else:
                    cspec = CompressionSpec(dtype=dtype, scope="cross-pod")
                    cs = dataclasses.replace(base, compression=cspec)
                    label = dtype
                comm = Communicator(mesh, cs, axes=axes)
                f = jax.jit(comm.wrap(comm.allreduce, in_specs=(spec,),
                                      out_specs=spec))
                st = ctx.measure(f, x)
                eff = gbps(logical, st["median_us"])
                if cspec is None:
                    wire, note = eff, "uncompressed"
                else:
                    wb = cspec.wire_bytes(n)
                    wire = gbps(wb, st["median_us"])
                    note = f"ratio={cspec.ratio(n):.2f}x"
                yield ctx.row(
                    f"compress_{tname}_{label}_{size}B", transport=tname,
                    ranks=ranks, size_bytes=size, stats=st, gbps=eff,
                    wire_gbps=wire, effective_gbps=eff, note=note)


# -------------------------------------------------------------- stream


@register_case("stream", figure="hpcc", ndev=1,
               description="HPCC STREAM triad local-bandwidth anchor")
def run_stream(ctx: BenchContext):
    import jax
    import jax.numpy as jnp

    @jax.jit
    def triad(b, c):
        return b + 3.0 * c

    for n in ctx.profile.stream_sizes:
        b = jnp.ones((n,), jnp.float32)
        c = jnp.ones((n,), jnp.float32)
        st = ctx.measure(triad, b, c)
        nbytes = 3 * 4 * n
        yield ctx.row(f"stream_triad_{n}", ranks=1, size_bytes=nbytes,
                      stats=st, gbps=gbps(nbytes, st["median_us"]))
