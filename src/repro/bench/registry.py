"""Declarative benchmark registry: one :class:`BenchCase` per paper
figure/table, one :class:`Profile` per size/iteration budget.

A case is a generator function ``impl(ctx) -> Iterable[row dict]``
registered with :func:`register_case`; the runner owns subprocess
placement (``case.ndev`` virtual devices) and sampling policy (the
profile's warmup/iters), so case bodies only build jitted callables and
yield rows via the :class:`BenchContext` helpers.  Registry metadata is
importable without jax — implementations import jax lazily, inside the
subprocess that owns the right device count.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterable, List, Optional, Tuple

# ---------------------------------------------------------------- profiles


@dataclasses.dataclass(frozen=True)
class Profile:
    """A size/iteration budget for the whole suite.

    ``full`` is the paper-faithful sweep (Fig 3 reaches 64 MB messages);
    ``ci`` bounds compile count and message sizes so the suite finishes
    in CI minutes; ``tiny`` is the test-suite smoke budget — every case
    must run under it on <= 2 virtual devices.
    """

    name: str
    warmup: int
    iters: int
    p2p_sizes: Tuple[int, ...]          # bytes (paper Fig 2/3)
    coll_sizes: Tuple[int, ...]         # per-rank bytes (paper Figs 5/7)
    coll_ranks: Tuple[int, ...]         # clamped to the live device count
    stream_sizes: Tuple[int, ...]       # elements (HPCC STREAM triad)
    gradex_bytes: int                   # gradient buffer, bytes
    modeled: bool                       # include modeled (v5e-scale) rows
    # served-traffic case (repro/bench/serving.py): request trace shape
    serve_requests: int = 6             # requests per trace
    serve_prompt_len: int = 24          # tokens per prompt
    serve_new_tokens: int = 8           # generated tokens per request
    serve_slots: int = 3                # engine decode batch
    serve_max_len: int = 64             # engine cache length
    serve_rate: float = 200.0           # mean Poisson arrivals per second
    # alltoall case (repro/bench/cases.py): MoE dispatch sub-benchmark
    moe_tokens: int = 64                # routed tokens per step
    moe_d_model: int = 32               # token width
    moe_d_ff: int = 64                  # expert FFN width
    moe_experts: int = 4                # global expert count (>= ranks)
    moe_top_k: int = 2                  # experts per token
    # OMB-Py / Charm4Py parity families (repro/bench/cases.py)
    msgrate_window: int = 16            # back-to-back messages per call
    overlap_sizes: Tuple[int, ...] = (1024, 4096)   # collective bytes
    overlap_compute_dim: int = 48       # per-rank matmul dim (overlap case)
    overlap_compute_iters: int = 2      # chained matmuls per slot
    overlap_slots: int = 4              # pipeline depth (slots per call)
    # grad_exchange train-step tie-in: overlap vs blocking full step
    gradex_step_batch: int = 8          # global batch of the timed step
    gradex_step_seq: int = 8            # sequence length
    gradex_step_mb: int = 2             # microbatches (pipeline depth)
    # elastic families (repro/bench/elastic.py)
    redist_shape: Tuple[int, int] = (256, 64)   # global Dmat extent
    recovery_steps: int = 6             # supervised run length (steps)
    # compression family: per-rank payload bytes (wire vs effective GB/s)
    compress_sizes: Tuple[int, ...] = (16 * 1024, 256 * 1024)


PROFILES: Dict[str, Profile] = {
    "full": Profile("full", warmup=2, iters=5,
                    p2p_sizes=tuple(16 * 4 ** i for i in range(12)),
                    coll_sizes=(8, 8 * 1024, 8 * 1024 * 1024),
                    coll_ranks=(2, 4, 8),
                    stream_sizes=(1 << 20, 1 << 24),
                    gradex_bytes=4 * 1024 * 1024, modeled=True,
                    serve_requests=16, serve_prompt_len=48,
                    serve_new_tokens=16, serve_slots=4,
                    serve_max_len=128, serve_rate=100.0,
                    moe_tokens=2048, moe_d_model=256, moe_d_ff=512,
                    moe_experts=16, moe_top_k=2,
                    msgrate_window=64,
                    overlap_sizes=(64 * 1024, 1024 * 1024),
                    overlap_compute_dim=128, overlap_compute_iters=8,
                    overlap_slots=16,
                    gradex_step_batch=32, gradex_step_seq=32,
                    gradex_step_mb=4,
                    redist_shape=(1024, 256), recovery_steps=8,
                    compress_sizes=(64 * 1024, 1 << 20, 8 << 20)),
    "ci": Profile("ci", warmup=2, iters=7,
                  p2p_sizes=(16, 1024, 64 * 1024, 1024 * 1024),
                  coll_sizes=(8, 8 * 1024, 256 * 1024),
                  coll_ranks=(2, 8),
                  stream_sizes=(1 << 20,),
                  gradex_bytes=1024 * 1024, modeled=True,
                  serve_requests=8, serve_prompt_len=32,
                  serve_new_tokens=8, serve_slots=3,
                  serve_max_len=64, serve_rate=200.0,
                  moe_tokens=512, moe_d_model=128, moe_d_ff=256,
                  moe_experts=8, moe_top_k=2,
                  msgrate_window=32,
                  overlap_sizes=(8 * 1024, 64 * 1024),
                  overlap_compute_dim=64, overlap_compute_iters=4,
                  overlap_slots=16,
                  gradex_step_batch=16, gradex_step_seq=16,
                  gradex_step_mb=4,
                  redist_shape=(256, 64), recovery_steps=6),
    "tiny": Profile("tiny", warmup=1, iters=2,
                    p2p_sizes=(16, 256),
                    coll_sizes=(8, 1024),
                    coll_ranks=(2,),
                    stream_sizes=(1 << 12,),
                    gradex_bytes=4096, modeled=True,
                    serve_requests=3, serve_prompt_len=8,
                    serve_new_tokens=3, serve_slots=2,
                    serve_max_len=32, serve_rate=1e6,
                    msgrate_window=8, overlap_sizes=(1024, 4096),
                    overlap_compute_dim=48, overlap_compute_iters=2,
                    overlap_slots=4,
                    gradex_step_batch=8, gradex_step_seq=8,
                    gradex_step_mb=2,
                    redist_shape=(32, 16), recovery_steps=4,
                    compress_sizes=(1024, 4096)),
}


def get_profile(name: str) -> Profile:
    try:
        return PROFILES[name]
    except KeyError:
        raise ValueError(f"unknown bench profile {name!r}; "
                         f"available: {sorted(PROFILES)}") from None


# ------------------------------------------------------------------ cases


@dataclasses.dataclass(frozen=True)
class BenchCase:
    """One paper figure/table: metadata + the row-yielding generator."""

    name: str                           # registry key ("p2p", "agg", ...)
    figure: str                         # paper anchor ("fig2/3", ...)
    ndev: int                           # virtual devices the full sweep wants
    measured: bool                      # False = purely modeled/derived
    description: str
    impl: Callable[["BenchContext"], Iterable[dict]]

    def run(self, ctx: "BenchContext") -> List[dict]:
        return list(self.impl(ctx))


_REGISTRY: Dict[str, BenchCase] = {}


def register_case(name: str, *, figure: str, ndev: int,
                  measured: bool = True, description: str = ""):
    """Decorator: register ``impl(ctx) -> Iterable[row]`` as a case."""
    def deco(fn):
        if name in _REGISTRY:
            raise ValueError(f"bench case {name!r} already registered")
        _REGISTRY[name] = BenchCase(name=name, figure=figure, ndev=ndev,
                                    measured=measured,
                                    description=description or
                                    (fn.__doc__ or "").strip().split("\n")[0],
                                    impl=fn)
        return fn
    return deco


def _ensure_loaded() -> None:
    # cases self-register on import; keep registry importable without them
    from repro.bench import cases, elastic, serving  # noqa: F401


def all_cases() -> Tuple[BenchCase, ...]:
    _ensure_loaded()
    return tuple(_REGISTRY[k] for k in sorted(_REGISTRY))


def get_case(name: str) -> BenchCase:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown bench case {name!r}; "
                         f"available: {sorted(_REGISTRY)}") from None


# ----------------------------------------------------------------- context


@dataclasses.dataclass
class BenchContext:
    """What a case body gets: the profile budget, the live device count,
    and row-construction helpers (so every row carries the same schema)."""

    case: BenchCase
    profile: Profile
    ndev: int

    def rank_counts(self) -> Tuple[int, ...]:
        """Profile rank sweep clamped to the live device count."""
        return tuple(sorted({min(r, self.ndev)
                             for r in self.profile.coll_ranks}))

    def measure(self, fn, *args) -> Dict[str, float]:
        from repro.bench.sampling import sample, stats_us
        return stats_us(sample(fn, *args, warmup=self.profile.warmup,
                               iters=self.profile.iters))

    def row(self, name: str, *, ranks: int, size_bytes: int,
            stats: Dict[str, float], transport: Optional[str] = None,
            gbps: Optional[float] = None, wire_gbps: Optional[float] = None,
            effective_gbps: Optional[float] = None, note: str = "") -> dict:
        """``wire_gbps`` rates the bytes that actually crossed the link
        (post-quantization payload + scales), ``effective_gbps`` the
        logical float32 payload the caller moved — the compression
        family reports both so the gate tracks real bytes moved."""
        return {
            "name": name, "case": self.case.name,
            "figure": self.case.figure, "transport": transport,
            "ranks": int(ranks), "size_bytes": int(size_bytes),
            "measured": True,
            "median_us": float(stats["median_us"]),
            "p95_us": float(stats["p95_us"]),
            "min_us": float(stats["min_us"]),
            "iters": self.profile.iters, "warmup": self.profile.warmup,
            "gbps": None if gbps is None else float(gbps),
            "wire_gbps": None if wire_gbps is None else float(wire_gbps),
            "effective_gbps": (None if effective_gbps is None
                               else float(effective_gbps)),
            "note": note,
        }

    def model_row(self, name: str, *, us: float, ranks: int,
                  size_bytes: int, transport: Optional[str] = None,
                  gbps: Optional[float] = None,
                  wire_gbps: Optional[float] = None,
                  effective_gbps: Optional[float] = None,
                  note: str = "") -> dict:
        """A modeled (analytic, not timed) row — v5e-scale extrapolation."""
        return {
            "name": name, "case": self.case.name,
            "figure": self.case.figure, "transport": transport,
            "ranks": int(ranks), "size_bytes": int(size_bytes),
            "measured": False,
            "median_us": float(us), "p95_us": float(us),
            "min_us": float(us), "iters": 0, "warmup": 0,
            "gbps": None if gbps is None else float(gbps),
            "wire_gbps": None if wire_gbps is None else float(wire_gbps),
            "effective_gbps": (None if effective_gbps is None
                               else float(effective_gbps)),
            "note": note,
        }
