"""CLI: ``python -m repro.bench`` / the ``repro-bench`` console script.

    repro-bench --out BENCH_ci.json            # run suite, write artifact
    repro-bench --profile full                 # paper-faithful sweep
    repro-bench --cases p2p,bcast --no-csv     # subset, JSON only
    repro-bench --baseline benchmarks/baseline.json --out ...   # run+gate
    repro-bench --list                         # show registered cases

Exit code: non-zero if any case subprocess failed, the roofline re-emit
hit a real bug, or (with ``--baseline``) the regression gate tripped.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from repro.bench import registry


def _parse(argv: Optional[Sequence[str]]) -> argparse.Namespace:
    p = argparse.ArgumentParser(
        prog="repro-bench",
        description="pPython-study benchmark suite (see repro/bench).")
    p.add_argument("--out", metavar="FILE",
                   help="write the schema-versioned JSON artifact here")
    p.add_argument("--profile", default="ci",
                   choices=sorted(registry.PROFILES),
                   help="size/iteration budget (default: ci)")
    p.add_argument("--cases", metavar="A,B,...",
                   help="comma-separated case subset (default: all)")
    p.add_argument("--no-csv", action="store_true",
                   help="suppress the legacy CSV on stdout")
    p.add_argument("--baseline", metavar="FILE",
                   help="after running, gate against this baseline "
                        "(see repro.bench.compare for thresholds)")
    p.add_argument("--threshold", type=float, default=None,
                   help="relative slowdown that fails the gate "
                        "(with --baseline)")
    p.add_argument("--noise-floor-us", type=float, default=None,
                   help="ignore absolute deltas below this (with "
                        "--baseline)")
    p.add_argument("--list", action="store_true",
                   help="list registered cases and exit")
    p.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    return p.parse_args(argv)


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = _parse(argv)
    names = args.cases.split(",") if args.cases else None

    if args.list:
        for c in registry.all_cases():
            print(f"{c.name:15s} {c.figure:8s} ndev={c.ndev}  "
                  f"{c.description}")
        return 0

    if args.child:
        from repro.bench.runner import child_main
        return child_main(names or [c.name for c in registry.all_cases()],
                          args.profile)

    from repro.bench import results
    from repro.bench.runner import print_csv, run_suite

    doc, failures = run_suite(names, profile=args.profile)
    if not args.no_csv:
        print_csv(doc["rows"])

    rc = 0
    if failures:
        # report before touching the artifact: a fully-failed suite has
        # no rows and results.write would reject it, masking the cause
        print(f"FAILED_SUITES,{len(failures)},{';'.join(failures)}")
        rc = 1
    if args.out:
        if doc["rows"]:
            results.write(doc, args.out)
            print(f"# wrote {args.out} ({len(doc['rows'])} rows, "
                  f"profile={doc['profile']}, sha={doc['git_sha'][:12]})",
                  file=sys.stderr)
        else:
            print(f"# no rows collected; not writing {args.out}",
                  file=sys.stderr)
    if args.baseline and not doc["rows"]:
        print("# no rows collected; skipping baseline compare",
              file=sys.stderr)
    elif args.baseline:
        from repro.bench import compare
        kw = {}
        if args.threshold is not None:
            kw["threshold"] = args.threshold
        if args.noise_floor_us is not None:
            kw["noise_floor_us"] = args.noise_floor_us
        base = results.load(args.baseline)
        report = compare.compare_docs(doc, base, **kw)
        compare.print_report(report)
        print(f"# gate: {'FAIL' if report['regressions'] else 'PASS'}")
        rc = max(rc, 1 if report["regressions"] else 0)
    return rc


if __name__ == "__main__":
    sys.exit(main())
