"""Regression gate: diff a bench run against a committed baseline.

    python -m repro.bench.compare BENCH_ci.json benchmarks/baseline.json

The gate compares ``min_us`` (best-of-N): for a fixed workload the
minimum is a far more stable statistic than the median under scheduler
noise — the artifact still records median/p95 for eyeballing.  A
measured row regresses when BOTH hold (noise tolerance):

    min_us > baseline * (1 + threshold)          relative slowdown
    min_us - baseline > noise_floor_us           absolute slack

Modeled/derived rows (``measured: false``) are compared for *presence*
only — their numbers are analytic, so a change there is a code change,
not a regression.

Shared CI boxes stall for seconds at a time, long enough to poison
every sample of a row in one run (observed: isolated 12x spikes).
``--also RUN2`` merges additional suite runs per-row by best-of before
gating: a slowdown then has to reproduce across independent runs on the
same row to fail, which scheduler stalls essentially never do and real
algorithmic regressions always do.  Rows present on one side only are reported but do not
fail the gate unless ``--strict-missing`` (case renames and profile
tweaks shouldn't brick CI); ``--warn-only`` reports everything and exits
0 — the PR-side soft gate, vs the hard gate on main/nightly.
"""
from __future__ import annotations

import argparse
import sys
from typing import Dict, List, Optional, Sequence

from repro.bench import results

# CI runners are shared, throttled VMs and the committed baseline may
# come from different hardware: the gate targets *algorithmic*
# regressions (accidental O(n^2), per-call recompiles, eager fallbacks
# — the 5-10x kind), so the thresholds must absorb multi-x scheduler
# noise.  Measured run-to-run jitter on a loaded box reaches ~2.5x on
# sub-millisecond rows even for best-of-N.
DEFAULT_THRESHOLD = 3.0         # fail at > 4x the baseline best-of-N
DEFAULT_NOISE_FLOOR_US = 200.0


def merge_runs(docs: Sequence[dict]) -> dict:
    """Per-row best-of across several suite runs (union of row names):
    the independent-reproduction defense against one-off scheduler
    stalls.  Header fields come from the first document."""
    rows: Dict[str, dict] = {}
    for d in docs:
        results.validate(d)
        for r in d["rows"]:
            cur = rows.get(r["name"])
            if cur is None or r["min_us"] < cur["min_us"]:
                rows[r["name"]] = r
    merged = dict(docs[0])
    merged["rows"] = [rows[k] for k in sorted(rows)]
    return merged


def compare_docs(run: dict, base: dict, *,
                 threshold: float = DEFAULT_THRESHOLD,
                 noise_floor_us: float = DEFAULT_NOISE_FLOOR_US) -> dict:
    """Pure comparison (no I/O): returns the report dict."""
    results.validate(run)
    results.validate(base)
    run_rows: Dict[str, dict] = {r["name"]: r for r in run["rows"]}
    base_rows: Dict[str, dict] = {r["name"]: r for r in base["rows"]}

    regressions: List[dict] = []
    improvements: List[dict] = []
    for name in sorted(set(run_rows) & set(base_rows)):
        r, b = run_rows[name], base_rows[name]
        if not (r["measured"] and b["measured"]):
            continue
        delta_us = r["min_us"] - b["min_us"]
        rel = delta_us / max(b["min_us"], 1e-9)
        entry = {"name": name, "base_us": b["min_us"],
                 "run_us": r["min_us"], "rel": rel}
        if rel > threshold and delta_us > noise_floor_us:
            regressions.append(entry)
        elif rel < -threshold / (1 + threshold) and -delta_us > noise_floor_us:
            improvements.append(entry)
    regressions.sort(key=lambda e: -e["rel"])
    improvements.sort(key=lambda e: e["rel"])
    return {
        "threshold": threshold, "noise_floor_us": noise_floor_us,
        "compared": len(set(run_rows) & set(base_rows)),
        "regressions": regressions, "improvements": improvements,
        "missing": sorted(set(base_rows) - set(run_rows)),
        "new": sorted(set(run_rows) - set(base_rows)),
        "run_sha": run.get("git_sha", "?"),
        "base_sha": base.get("git_sha", "?"),
    }


def print_report(rep: dict, file=None) -> None:
    out = file or sys.stdout
    print(f"# bench compare: {rep['compared']} shared rows "
          f"(run {rep['run_sha'][:12]} vs base {rep['base_sha'][:12]}), "
          f"threshold +{rep['threshold'] * 100:.0f}%, "
          f"noise floor {rep['noise_floor_us']:.0f}us", file=out)
    for e in rep["regressions"]:
        print(f"REGRESSION {e['name']}: {e['base_us']:.1f}us -> "
              f"{e['run_us']:.1f}us (+{e['rel'] * 100:.0f}%)", file=out)
    for e in rep["improvements"]:
        print(f"improvement {e['name']}: {e['base_us']:.1f}us -> "
              f"{e['run_us']:.1f}us ({e['rel'] * 100:.0f}%)", file=out)
    if rep["missing"]:
        print(f"# missing vs baseline ({len(rep['missing'])}): "
              + ", ".join(rep["missing"][:8])
              + ("..." if len(rep["missing"]) > 8 else ""), file=out)
    if rep["new"]:
        print(f"# new rows not in baseline: {len(rep['new'])}", file=out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.bench.compare",
        description="Gate a bench run against a baseline artifact.")
    p.add_argument("run", help="BENCH_*.json from python -m repro.bench")
    p.add_argument("baseline", help="committed baseline artifact")
    p.add_argument("--also", action="append", default=[], metavar="RUN2",
                   help="additional suite runs merged per-row by "
                        "best-of before gating (repeatable) — a "
                        "slowdown must reproduce in every run to fail")
    p.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                   help="relative slowdown that fails (default: "
                        f"{DEFAULT_THRESHOLD})")
    p.add_argument("--noise-floor-us", type=float,
                   default=DEFAULT_NOISE_FLOOR_US,
                   help="ignore absolute deltas below this (default: "
                        f"{DEFAULT_NOISE_FLOOR_US})")
    p.add_argument("--strict-missing", action="store_true",
                   help="also fail when baseline rows are missing from "
                        "the run")
    p.add_argument("--warn-only", action="store_true",
                   help="report but always exit 0 (PR soft gate)")
    args = p.parse_args(argv)

    run_doc = merge_runs([results.load(args.run)]
                         + [results.load(p) for p in args.also])
    rep = compare_docs(run_doc, results.load(args.baseline),
                       threshold=args.threshold,
                       noise_floor_us=args.noise_floor_us)
    print_report(rep)
    failed = bool(rep["regressions"]) or (args.strict_missing
                                          and bool(rep["missing"]))
    if not failed:
        print("# gate: PASS")
        return 0
    if args.warn_only:
        print("# gate: FAIL (warn-only mode, exiting 0)")
        return 0
    print("# gate: FAIL")
    return 1


if __name__ == "__main__":
    sys.exit(main())
