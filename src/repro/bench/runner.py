"""Suite execution.

The parent process never initializes jax devices: each group of cases
runs in a child ``python -m repro.bench --child`` subprocess launched
with ``XLA_FLAGS=--xla_force_host_platform_device_count=<ndev>``, and
streams its rows back as marker-prefixed JSON lines on stdout (anything
else the child prints passes through untouched).  The roofline summary
is re-emitted parent-side as derived rows: a *missing* roofline module
degrades to an ``unavailable`` row, but a *bug* in it propagates — the
old bare ``except Exception`` in ``benchmarks/run.py`` swallowed real
errors behind the same message.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.bench import registry, results

ROW_MARKER = "@@BENCH-ROW@@ "


def effective_ndev(case: registry.BenchCase, profile: registry.Profile
                   ) -> int:
    """Device count a case runs under: the case's preferred count, capped
    by the profile's rank budget (tiny runs fit on 2 devices)."""
    cap = max(max(profile.coll_ranks), 2)
    return max(1, min(case.ndev, cap))


def run_cases_inline(names: Sequence[str], profile: str = "ci"
                     ) -> List[dict]:
    """Run cases in *this* process against however many devices exist —
    the child-side entry, also used directly by tests and the old
    ``benchmarks/<case>.py`` shims (which set XLA_FLAGS themselves)."""
    import jax

    prof = registry.get_profile(profile)
    live = len(jax.devices())
    rows: List[dict] = []
    for name in names:
        case = registry.get_case(name)
        ctx = registry.BenchContext(case=case, profile=prof,
                                    ndev=min(case.ndev, live))
        rows.extend(case.run(ctx))
    return rows


def child_main(names: Sequence[str], profile: str) -> int:
    """Entry for ``python -m repro.bench --child``: emit one marker line
    per row; the parent owns aggregation and artifacts."""
    for row in run_cases_inline(names, profile):
        print(ROW_MARKER + json.dumps(row), flush=True)
    return 0


def _run_child(ndev: int, names: Sequence[str], profile: str
               ) -> Tuple[List[dict], int]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env.setdefault("JAX_PLATFORMS", "cpu")
    src_root = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src_root, env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.bench", "--child",
         "--cases", ",".join(names), "--profile", profile],
        env=env, capture_output=True, text=True)
    rows: List[dict] = []
    for line in proc.stdout.splitlines():
        if line.startswith(ROW_MARKER):
            rows.append(json.loads(line[len(ROW_MARKER):]))
        elif line.strip():
            print(line, file=sys.stderr)  # pass through child chatter
    if proc.returncode and proc.stderr:
        sys.stderr.write(proc.stderr)
    return rows, proc.returncode


def roofline_rows() -> List[dict]:
    """Derived roofline summary rows (no timing).  ImportError (module
    genuinely absent in a stripped install) degrades to an 'unavailable'
    row; any other failure is a bug in repro.roofline and propagates."""
    try:
        from repro.roofline import analysis
    except ImportError as e:
        return [_derived_row("roofline_summary", f"unavailable:{e}")]
    rows = [r for c in analysis.load_cells()
            if (r := analysis.roofline_row(c))]
    out = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(_derived_row(
            f"roofline_{r['arch']}_{r['shape']}",
            f"bound={r['dominant']};frac={r['roofline_fraction']:.4f};"
            f"useful={r['useful_ratio']:.2f}"))
    return out


def _derived_row(name: str, note: str) -> dict:
    return {"name": name, "case": "roofline", "figure": "roofline",
            "transport": None, "ranks": 1, "size_bytes": 0,
            "measured": False, "median_us": 0.0, "p95_us": 0.0,
            "min_us": 0.0, "iters": 0, "warmup": 0, "gbps": None,
            "note": note}


def run_suite(names: Optional[Sequence[str]] = None, profile: str = "ci",
              with_roofline: bool = True
              ) -> Tuple[dict, List[str]]:
    """Run the suite in per-device-count subprocesses; returns the
    results document and the list of failed case groups."""
    cases = ([registry.get_case(n) for n in names] if names
             else list(registry.all_cases()))
    prof = registry.get_profile(profile)
    groups: Dict[int, List[registry.BenchCase]] = {}
    for c in cases:
        groups.setdefault(effective_ndev(c, prof), []).append(c)

    rows: List[dict] = []
    device_counts: Dict[str, int] = {}
    failures: List[str] = []
    for ndev in sorted(groups):
        group_names = [c.name for c in groups[ndev]]
        got, rc = _run_child(ndev, group_names, profile)
        rows.extend(got)
        for c in groups[ndev]:
            device_counts[c.name] = ndev
        if rc:
            failures.append(f"ndev={ndev}:{','.join(group_names)}")
    if with_roofline:
        rows.extend(roofline_rows())
    doc = results.new_document(profile, rows, device_counts)
    return doc, failures


def print_csv(rows: Iterable[dict]) -> None:
    for line in results.csv_lines(rows):
        print(line)
