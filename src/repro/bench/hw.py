"""v5e link model constants for the modeled (256..768-rank) extension of
the paper's sweep — CPU cannot measure those scales.  Bandwidths are the
single source of truth in :mod:`repro.roofline.analysis`; the latencies
are the per-hop terms the point-to-point model adds on top."""
from repro.roofline.analysis import DCI_BW, ICI_BW  # noqa: F401

ICI_LAT = 1e-6     # s per in-pod hop
DCI_LAT = 10e-6    # s per cross-pod hop
