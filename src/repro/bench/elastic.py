"""Elastic families: resharding bandwidth and detect-to-resume time.

``redistribute`` times the capability pMatlab/pPython name as the
library's core — moving a distributed array between two maps — both
ways we implement it:

    redist_stream_<pair>_<t>   streamed Communicator.redistribute (one
                               scheduled Alltoallv from the static
                               (counts, send, recv) plan) over
                               transport ``<t>``;
    redist_gather_<pair>       the composed-static-gather reference
                               (GSPMD emits the communication).

Rows carry the global array bytes and derived GB/s — resharding
bandwidth is a figure no related repo publishes.

``recovery`` runs the RecoverySupervisor under an armed FaultPlan whose
schedule kills half the devices mid-run (shrink remesh + checkpoint
restore + replay) and later restores them (grow remesh + LIVE state
redistribution, no checkpoint round-trip), and reports each event's
**detect-to-resume** seconds: exception observed -> first step
completed on the new mesh (includes the re-jit, which is honest for
this container).
"""
from __future__ import annotations

from repro.bench.registry import BenchContext, register_case

ARCH = "h2o-danube-1.8b"


def _map_pairs(n: int, shape):
    """(label, src, dst) Dmap pairs adapted to ``n`` ranks — at least
    two distinct layout changes, incl. a block-cyclic+overlap target."""
    from repro.core.dmap import Dmap

    pairs = [
        ("rowcol", Dmap(grid=(n, 1)), Dmap(grid=(1, n))),
        ("bc_ov", Dmap(grid=(n, 1)),
         Dmap(grid=(n, 1), dist=(("bc", 2), ("b",)), overlap=(1, 0))),
    ]
    if n >= 4:
        pairs.append(("grid", Dmap(grid=(n // 2, 2)),
                      Dmap(grid=(2, n // 2), dist=(("c",), ("b",)))))
    return pairs


@register_case("redistribute", figure="elastic", ndev=8,
               description="Dmap-to-Dmap resharding GB/s: streamed "
                           "Alltoallv plan vs composed-gather reference")
def run_redistribute(ctx: BenchContext):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.bench.sampling import gbps
    from repro.comms import Communicator
    from repro.core import dmat
    from repro.core.dmap import redistribution_plan

    n = max(ctx.ndev, 2)
    shape = tuple(ctx.profile.redist_shape)
    size_bytes = 4
    for s in shape:
        size_bytes *= s
    mesh = jax.make_mesh((n,), ("r",))
    arr = jnp.arange(float(shape[0] * shape[1]),
                     dtype=jnp.float32).reshape(shape)

    for label, src, dst in _map_pairs(n, shape):
        d = dmat.Dmat.from_global(arr, src, mesh)
        counts, _, _ = redistribution_plan(src, dst, shape, n)
        wire = int(counts.sum()) * 4
        for tname in ("native", "tree"):
            comm = Communicator(mesh, tname, axes=("r",))

            def body(block, c=comm, s=src, t=dst):
                return c.redistribute(block, s, t, shape)

            fn = jax.jit(comm.wrap(body, in_specs=(P("r"),),
                                   out_specs=P("r")))
            st = ctx.measure(fn, d.storage)
            yield ctx.row(f"redist_stream_{label}_{tname}",
                          transport=tname, ranks=n, size_bytes=size_bytes,
                          stats=st,
                          gbps=gbps(size_bytes, st["median_us"]),
                          note=f"wire_bytes={wire} shape={shape}")

        def gather_fn(storage, s=src, t=dst):
            return dmat.Dmat(storage, s, shape, mesh).redistribute(
                t, method="gather").storage

        fng = jax.jit(gather_fn)
        st = ctx.measure(fng, d.storage)
        yield ctx.row(f"redist_gather_{label}", transport="gspmd",
                      ranks=n, size_bytes=size_bytes, stats=st,
                      gbps=gbps(size_bytes, st["median_us"]),
                      note=f"shape={shape}")


@register_case("recovery", figure="elastic", ndev=8,
               description="detect-to-resume seconds across a "
                           "lose/shrink and a restore/grow event")
def run_recovery(ctx: BenchContext):
    import tempfile

    from repro.bench.sampling import stats_us
    from repro.comms import faults
    from repro.configs.base import ShapeSpec, get_config, reduced
    from repro.train.recovery import RecoveryConfig, RecoverySupervisor
    from repro.train.trainer import TrainerConfig

    n = max(ctx.ndev, 2)
    steps = max(ctx.profile.recovery_steps, 4)
    lose_step, restore_step = steps // 2, steps - 1
    plan = faults.FaultPlan(events=(
        faults.HostEvent(lose_step, faults.LOSE, max(n // 2, 1)),
        faults.HostEvent(restore_step, faults.RESTORE, n)))

    cfg = reduced(get_config(ARCH))
    shape = ShapeSpec("bench", "train", 16, 8)
    sup = RecoverySupervisor(
        cfg, shape,
        TrainerConfig(total_steps=steps, checkpoint_every=2,
                      ckpt_dir=tempfile.mkdtemp(prefix="bench_recovery_"),
                      log_every=10 ** 9),
        RecoveryConfig(model_width=1))
    with faults.armed(plan):
        out = sup.run(n_devices=n)
    assert out["recoveries"] == 2, out["events"]
    shrink_s, grow_s = out["detect_to_resume_s"]
    yield ctx.row("recovery_shrink_resume", ranks=n, size_bytes=0,
                  stats=stats_us([shrink_s]),
                  note=f"lose {n}->{max(n // 2, 1)} at step {lose_step}; "
                       f"ckpt restore + replay")
    yield ctx.row("recovery_grow_resume", ranks=n, size_bytes=0,
                  stats=stats_us([grow_s]),
                  note=f"restore ->{n} at step {restore_step}; "
                       f"live redistribute, no ckpt round-trip")
