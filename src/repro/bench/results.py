"""Schema-versioned benchmark artifacts (``BENCH_<timestamp>.json``).

The JSON document is the durable record CI uploads and the regression
gate consumes; the legacy ``name,us_per_call,derived`` CSV remains on
stdout for eyeballing and for the old ``benchmarks/run.py`` consumers.
``validate`` is deliberately strict — compare.py and the tests both run
it, so a malformed artifact fails loudly instead of gating on garbage.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
from typing import Dict, Iterable, List

SCHEMA = "repro-bench"
# version 2: rows may carry ``wire_gbps``/``effective_gbps`` (the
# compression family rates real bytes-on-wire separately from the
# logical float32 payload)
SCHEMA_VERSION = 2

_ROW_FIELDS = {
    "name": str, "case": str, "figure": str, "ranks": int,
    "size_bytes": int, "measured": bool, "median_us": (int, float),
    "p95_us": (int, float), "min_us": (int, float), "iters": int,
    "warmup": int, "note": str,
}
_OPTIONAL_ROW_FIELDS = ("transport", "gbps", "wire_gbps",
                        "effective_gbps")  # may be null/absent
#: optional fields that, when present, must be non-negative numbers
_RATE_FIELDS = ("gbps", "wire_gbps", "effective_gbps")


def git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=10)
        return out.stdout.strip() if out.returncode == 0 else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def jax_version() -> str:
    try:
        from importlib.metadata import version
        return version("jax")
    except Exception:  # metadata missing in odd installs — not fatal
        return "unknown"


def new_document(profile: str, rows: List[dict],
                 device_counts: Dict[str, int]) -> dict:
    return {
        "schema": SCHEMA,
        "schema_version": SCHEMA_VERSION,
        "created_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "git_sha": git_sha(),
        "jax_version": jax_version(),
        "profile": profile,
        "device_counts": dict(device_counts),
        "rows": list(rows),
    }


def validate(doc: dict) -> None:
    """Raise ValueError on any schema violation."""
    if not isinstance(doc, dict):
        raise ValueError("results document must be a JSON object")
    if doc.get("schema") != SCHEMA:
        raise ValueError(f"schema must be {SCHEMA!r}, got "
                         f"{doc.get('schema')!r}")
    if doc.get("schema_version") != SCHEMA_VERSION:
        raise ValueError(f"schema_version must be {SCHEMA_VERSION}, got "
                         f"{doc.get('schema_version')!r}")
    for key in ("created_utc", "git_sha", "jax_version", "profile"):
        if not isinstance(doc.get(key), str):
            raise ValueError(f"missing/non-string top-level field {key!r}")
    dc = doc.get("device_counts")
    if not isinstance(dc, dict) or not all(
            isinstance(k, str) and isinstance(v, int) for k, v in dc.items()):
        raise ValueError("device_counts must map case name -> int")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        raise ValueError("rows must be a non-empty list")
    seen = set()
    for i, row in enumerate(rows):
        if not isinstance(row, dict):
            raise ValueError(f"rows[{i}] is not an object")
        for field, typ in _ROW_FIELDS.items():
            v = row.get(field)
            ok = isinstance(v, typ)
            if ok and typ is not bool and isinstance(v, bool):
                ok = False  # bool satisfies isinstance(.., int); reject it
            if not ok:
                raise ValueError(f"rows[{i}] ({row.get('name')!r}): field "
                                 f"{field!r} must be {typ}, got {v!r}")
        for field in _OPTIONAL_ROW_FIELDS:
            v = row.get(field)
            if v is not None and not isinstance(v, (str, int, float)):
                raise ValueError(f"rows[{i}]: bad optional field {field!r}")
        for field in _RATE_FIELDS:
            v = row.get(field)
            if v is None:
                continue
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                raise ValueError(f"rows[{i}] ({row.get('name')!r}): "
                                 f"{field!r} must be a number or null, "
                                 f"got {v!r}")
            if v < 0:
                raise ValueError(f"rows[{i}] ({row.get('name')!r}): "
                                 f"negative {field!r}")
        if row["median_us"] < 0 or row["min_us"] < 0:
            raise ValueError(f"rows[{i}]: negative timing")
        if not row["min_us"] <= row["median_us"] <= row["p95_us"]:
            raise ValueError(f"rows[{i}] ({row['name']!r}): "
                             "min/median/p95 out of order")
        if row["name"] in seen:
            raise ValueError(f"duplicate row name {row['name']!r}")
        seen.add(row["name"])


def write(doc: dict, path: str) -> None:
    validate(doc)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")


def load(path: str) -> dict:
    with open(path) as fh:
        doc = json.load(fh)
    validate(doc)
    return doc


def csv_lines(rows: Iterable[dict]) -> Iterable[str]:
    """The legacy stdout format: ``name,us_per_call,derived``."""
    yield "name,us_per_call,derived"
    for r in rows:
        if r.get("gbps") is not None:
            derived = f"{r['gbps']:.3f}GB/s"
        else:
            derived = r.get("note", "")
        yield f"{r['name']},{r['median_us']:.1f},{derived}"
