"""Served-traffic benchmark: the serving engine under a deterministic
Poisson-like arrival trace.

One case, four row families per cache mode (paged and dense):

    serve_ttft_<mode>   p95/median time-to-first-token over the trace's
                        requests (us); samples = per-request TTFTs,
                        pooled over the profile's measured repetitions.
    serve_tok_<mode>    per-generated-token wall time (us/token) per
                        trace repetition; tokens/sec in the note.

Arrivals are ``rng.exponential(1 / serve_rate)`` inter-arrival gaps
from a fixed seed — deterministic across runs, Poisson-shaped in
profile.  The first (warmup) traces compile both dispatch widths, so
measured rows see steady-state behavior; the compare gate in CI treats
these rows like any other (threshold + noise floor).
"""
from __future__ import annotations

from repro.bench.registry import BenchContext, register_case

ARCH = "gemma3-4b"


def _trace(prof, vocab: int):
    import numpy as np

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, vocab, size=prof.serve_prompt_len)
               for _ in range(prof.serve_requests)]
    arrivals = np.cumsum(
        rng.exponential(1.0 / prof.serve_rate, size=prof.serve_requests))
    return prompts, [float(t) for t in arrivals]


def _run_trace(engine, prof, prompts, arrivals, vocab: int):
    """One full trace; returns (per-request TTFTs s, elapsed s, tokens)."""
    from repro.serve import Request

    reqs = [Request(rid=i, prompt=p, max_new_tokens=prof.serve_new_tokens)
            for i, p in enumerate(prompts)]
    res = engine.run_trace(reqs, arrivals)
    assert not res.truncated and len(res) == len(reqs)
    tokens = sum(len(v) for v in res.values())
    ttfts = [m["ttft_s"] for m in res.metrics.values()
             if m.get("ttft_s") is not None]
    elapsed = max(m["done_s"] for m in res.metrics.values()) - min(arrivals)
    return ttfts, elapsed, tokens


@register_case("serving", figure="serve", ndev=1,
               description="served-traffic tokens/sec and p95 TTFT, "
                           "paged vs dense KV cache, Poisson arrivals")
def run_serving(ctx: BenchContext):
    import jax
    from repro.bench.sampling import stats_us
    from repro.configs.base import get_config, reduced
    from repro.launch.mesh import mesh_for_devices
    from repro.models.model import Model
    from repro.serve import Engine

    prof = ctx.profile
    cfg = reduced(get_config(ARCH))
    mesh = mesh_for_devices(1)
    params = Model(cfg, mesh).init(jax.random.PRNGKey(0))
    prompts, arrivals = _trace(prof, cfg.vocab_size)

    for mode in ("paged", "dense"):
        engine = Engine(cfg, mesh, slots=prof.serve_slots,
                        max_len=prof.serve_max_len, cache_mode=mode)
        engine.load(params)
        for _ in range(max(prof.warmup, 1)):   # compile both tick widths
            _run_trace(engine, prof, prompts, arrivals, cfg.vocab_size)
        ttfts, per_tok, total = [], [], 0
        for _ in range(max(prof.iters, 1)):
            t, elapsed, n = _run_trace(engine, prof, prompts, arrivals,
                                       cfg.vocab_size)
            ttfts.extend(t)
            per_tok.append(elapsed / max(n, 1))
            total = n
        tok_s = 1.0 / (sorted(per_tok)[len(per_tok) // 2])
        yield ctx.row(f"serve_ttft_{mode}", ranks=1,
                      size_bytes=prof.serve_prompt_len,
                      stats=stats_us(ttfts),
                      note=f"requests={prof.serve_requests} "
                           f"slots={prof.serve_slots}")
        yield ctx.row(f"serve_tok_{mode}", ranks=1, size_bytes=total,
                      stats=stats_us(per_tok),
                      note=f"tok_s={tok_s:.0f} "
                           f"new={prof.serve_new_tokens}")
