"""Llama-4-Maverick-400B-A17B: 48L, MoE 128 experts top-1 + shared expert,
alternating dense/MoE layers.  [hf:meta-llama/Llama-4-Scout-17B-16E;
unverified].  Early-fusion multimodality is out of backbone scope (the
assignment specifies the LM backbone; text tokens only here).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,                    # per-expert FFN width
    dense_d_ff=16384,             # interleaved dense layers
    vocab_size=202048,
    rope_theta=500_000.0,
    num_experts=128,
    top_k=1,
    num_shared_experts=1,
    moe_every=2,                  # MoE on every 2nd layer
    capacity_factor=1.25,
    microbatches=16,
    use_fsdp=True,
    use_pod_fsdp=True,
    optimizer="adafactor",
    skip_shapes=("long_500k",),
    skip_reason="pure full attention",
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))
