"""DeepSeek-7B: 30L llama-arch, MHA (kv=32).  [arXiv:2401.02954; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    rope_theta=10_000.0,
    microbatches=8,
    use_fsdp=True,
    # §Perf: with heads TP-sharded 16-way the per-device logits buffer is
    # small, so query chunking only multiplies KV re-reads — disabling it
    # cut the memory roofline term 172s -> 78s (numerics unchanged).
    attn_q_chunk=0,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention, MHA kv=32",
    source="arXiv:2401.02954; hf",
))
