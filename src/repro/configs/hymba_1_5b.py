"""Hymba-1.5B: 32L hybrid with parallel attention + mamba(SSM) heads in
every block.  [arXiv:2411.13676; hf].

Per the paper, most layers use sliding-window attention (1024) with the
first/middle/last layers global; the SSM branch gives O(1)-state decode,
so long_500k runs.  Meta tokens are out of backbone scope.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    full_attn_layers=(0, 15, 31),
    ssm_state=16,
    ssm_d_inner=3200,
    microbatches=4,
    source="arXiv:2411.13676; hf",
))
