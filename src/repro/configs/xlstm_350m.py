"""xLSTM-350M: 24 blocks alternating mLSTM/sLSTM, d_ff=0 (no separate FFN).

[arXiv:2405.04517; unverified].  The xLSTM[1:1] pattern interleaves
matrix-memory (mLSTM, parallelizable/chunkwise) and scalar-memory (sLSTM,
strictly sequential) blocks.  Recurrent state makes long_500k decoding
O(1) per token, so the long-context cell runs for this arch.
"""
from repro.configs.base import ArchConfig, MLSTM, SLSTM, register

CONFIG = register(ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    xlstm_pattern=(MLSTM, SLSTM),
    microbatches=2,
    prefill_chunk=4096,
    # §Perf: with 4 heads x dh=256, model-axis TP makes GSPMD reshard tiny
    # per-timestep tensors inside the sLSTM scan ("involuntary full
    # rematerialization") — pure data parallelism over the whole mesh cut
    # the memory roofline term 340s -> 136s.
    shard_strategy="replicate",
    source="arXiv:2405.04517; unverified",
))
