"""Architecture + shape configuration for the repro framework.

Every assigned architecture is a frozen :class:`ArchConfig`.  The four
assigned input shapes are :data:`SHAPES`.  ``input_specs`` produces
``jax.ShapeDtypeStruct`` stand-ins for every model input so the multi-pod
dry-run can ``.lower().compile()`` without allocating anything.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Shapes (assigned; seq_len x global_batch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# Layer-kind tags used by the stack builder -------------------------------
ATTN = "attn"          # self attention (window controlled per-layer)
XATTN = "xattn"        # cross attention (vision / enc-dec)
MLSTM = "mlstm"        # xLSTM matrix-memory block
SLSTM = "slstm"        # xLSTM scalar-memory block
HYMBA = "hymba"        # parallel attention + SSM heads
GLOBAL_WINDOW = 1 << 30  # sentinel: "no window" (full attention)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture.  All sizes are exact per the assignment."""

    name: str
    family: str                    # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128

    # --- attention pattern -------------------------------------------------
    sliding_window: int = 0        # 0 => full attention everywhere
    # every `global_every`-th layer (1-indexed) is full/global; others local.
    global_every: int = 0          # 0 => homogeneous
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0  # gemma3: different theta on global layers
    # vision: every `xattn_every`-th layer is a cross-attention layer
    xattn_every: int = 0
    num_image_tokens: int = 0      # vlm frontend stub width
    # audio/enc-dec
    encoder_layers: int = 0
    src_seq_len: int = 0           # frontend stub sequence length

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    moe_every: int = 1             # 1 => every layer MoE; 2 => alternate
    first_dense_layers: int = 0    # leading dense layers (Kimi-K2 style)
    dense_d_ff: int = 0            # d_ff of the dense layers in MoE archs
    capacity_factor: float = 1.25
    # transport carrying the expert-parallel dispatch/combine exchange
    # (repro.comms registry name; see Communicator.alltoall)
    moe_comms: str = "native"

    # --- SSM / hybrid ------------------------------------------------------
    ssm_state: int = 0
    ssm_d_inner: int = 0
    # xLSTM: pattern of (MLSTM, SLSTM) repeated
    xlstm_pattern: Tuple[str, ...] = ()
    full_attn_layers: Tuple[int, ...] = ()  # hymba: layers forced global

    # --- training / memory knobs -------------------------------------------
    microbatches: int = 8          # grad-accumulation steps in train_step
    prefill_chunk: int = 4_096     # chunked-prefill granularity
    use_fsdp: bool = False         # shard params over the data axis
    use_pod_fsdp: bool = False     # additionally shard over the pod axis
    optimizer: str = "adamw"       # adamw | adafactor
    remat: bool = True
    tie_embeddings: bool = False

    # --- perf-iteration knobs (EXPERIMENTS.md §Perf) ------------------------
    attn_q_chunk: int = 512        # 0 => no query chunking
    attn_logits_dtype: str = "f32"  # f32 | bf16 (XLA-path logits buffer)
    ssm_scan_dtype: str = "f32"    # f32 | bf16 (selective-scan elements)
    mlstm_dtype: str = "f32"       # f32 | bf16 (xLSTM matmul operands)
    mlstm_chunk: int = 256         # chunkwise-mLSTM chunk length
    expert_gather_dtype: str = "bf16"   # bf16 | int8 (FSDP expert gathers)
    remat_policy: str = "nothing"  # nothing | dots
    # 'tp': model-axis tensor parallelism on block weights.  'replicate':
    # no TP on block weights (vocab/embedding stay model-sharded) — the
    # right call for small-width recurrent archs where GSPMD otherwise
    # reshards tiny per-step tensors inside the time scan (§Perf).
    shard_strategy: str = "tp"

    # --- bookkeeping --------------------------------------------------------
    skip_shapes: Tuple[str, ...] = ()   # e.g. ('long_500k',)
    skip_reason: str = ""
    source: str = ""

    # ----------------------------------------------------------------- utils
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind, length == num_layers (+ encoder handled apart)."""
        if self.xlstm_pattern:
            reps = self.num_layers // len(self.xlstm_pattern)
            return tuple(self.xlstm_pattern) * reps
        if self.family == "hybrid":
            return (HYMBA,) * self.num_layers
        kinds = []
        for i in range(self.num_layers):
            if self.xattn_every and (i + 1) % self.xattn_every == 0:
                kinds.append(XATTN)
            else:
                kinds.append(ATTN)
        return tuple(kinds)

    def layer_windows(self) -> Tuple[int, ...]:
        """Per-layer attention window (GLOBAL_WINDOW => full)."""
        out = []
        for i in range(self.num_layers):
            if self.sliding_window <= 0:
                out.append(GLOBAL_WINDOW)
            elif self.global_every and (i + 1) % self.global_every == 0:
                out.append(GLOBAL_WINDOW)
            elif i in self.full_attn_layers:
                out.append(GLOBAL_WINDOW)
            else:
                out.append(self.sliding_window)
        return tuple(out)

    def layer_thetas(self) -> Tuple[float, ...]:
        out = []
        windows = self.layer_windows()
        for i in range(self.num_layers):
            if self.rope_theta_global and windows[i] == GLOBAL_WINDOW:
                out.append(self.rope_theta_global)
            else:
                out.append(self.rope_theta)
        return tuple(out)

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        """True for layers whose FFN is MoE."""
        if not self.num_experts:
            return (False,) * self.num_layers
        out = []
        for i in range(self.num_layers):
            if i < self.first_dense_layers:
                out.append(False)
            elif self.moe_every > 1 and (i % self.moe_every) != (self.moe_every - 1):
                out.append(False)
            else:
                out.append(True)
        return tuple(out)

    # Parameter count (for MODEL_FLOPS = 6*N*D roofline bookkeeping) -------
    def param_count(self, active_only: bool = False) -> int:
        D, V = self.d_model, self.vocab_size
        n = V * D  # token embedding
        if not self.tie_embeddings:
            n += V * D
        kinds = self.layer_kinds()
        moe_mask = self.moe_layer_mask()
        for i, kind in enumerate(kinds):
            n += 2 * D  # pre norms
            if kind in (ATTN, XATTN, HYMBA):
                n += D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            if kind == XATTN:  # extra self-attn stays; xattn replaces ffn? no:
                pass
            if kind == HYMBA:
                di = self.ssm_d_inner
                n += D * 2 * di + di * self.ssm_state * 2 + di * 2 + di * D
            if kind == MLSTM:
                # qkv + gates + out
                n += 3 * D * self.q_dim + 2 * D * self.num_heads + self.q_dim * D
            if kind == SLSTM:
                n += 4 * D * self.q_dim + 4 * self.num_heads * self.head_dim ** 2 \
                    + self.q_dim * D
            # FFN
            if kind in (MLSTM, SLSTM):
                continue  # xLSTM: d_ff == 0
            if moe_mask[i]:
                ff = self.d_ff
                per_expert = 3 * D * ff
                if active_only:
                    n += (self.top_k + self.num_shared_experts) * per_expert
                    n += D * self.num_experts  # router
                else:
                    n += (self.num_experts + self.num_shared_experts) * per_expert
                    n += D * self.num_experts
            else:
                ff = self.dense_d_ff or self.d_ff
                if ff:
                    n += 3 * D * ff
        # encoder (enc-dec archs)
        for _ in range(self.encoder_layers):
            n += 2 * D
            n += D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            n += 3 * D * self.d_ff
        if self.encoder_layers:  # decoder cross-attn params
            n += self.num_layers * (D * self.q_dim + 2 * D * self.kv_dim
                                    + self.q_dim * D + D)
        if self.xattn_every:
            n_x = sum(1 for k in kinds if k == XATTN)
            # xattn layers already counted their self-attn; add kv/gate extra
            n += n_x * (2 * D * self.kv_dim + 2)
        return n


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    from repro.configs import (  # noqa: F401
        llama32_vision_11b, xlstm_350m, h2o_danube_1_8b, gemma3_4b,
        starcoder2_7b, deepseek_7b, llama4_maverick, kimi_k2, hymba_1_5b,
        seamless_m4t_medium, ppython_bench,
    )


def reduced(cfg: ArchConfig, **overrides: Any) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    base = dict(
        num_layers=max(2, min(4, cfg.num_layers)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) or 2,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        num_experts=min(cfg.num_experts, 8),
        top_k=min(cfg.top_k, 2),
        num_shared_experts=min(cfg.num_shared_experts, 1),
        dense_d_ff=128 if cfg.dense_d_ff else 0,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        num_image_tokens=16 if cfg.num_image_tokens else 0,
        encoder_layers=2 if cfg.encoder_layers else 0,
        src_seq_len=16 if cfg.src_seq_len else 0,
        ssm_state=cfg.ssm_state,
        ssm_d_inner=128 if cfg.ssm_d_inner else 0,
        microbatches=1,
        prefill_chunk=8,
        use_fsdp=False,
        use_pod_fsdp=False,
        full_attn_layers=(0,) if cfg.full_attn_layers else (),
    )
    if cfg.xlstm_pattern:
        base["xlstm_pattern"] = cfg.xlstm_pattern
        base["num_layers"] = 2 * len(cfg.xlstm_pattern)
        base["d_ff"] = 0
    if cfg.xattn_every:
        base["xattn_every"] = min(cfg.xattn_every, 2)
        base["num_layers"] = 4
    if cfg.global_every:
        base["global_every"] = min(cfg.global_every, 2)
    base.update(overrides)
    return dataclasses.replace(cfg, **base)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs, no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Model inputs for one (arch x shape) cell as ShapeDtypeStructs.

    train  : tokens/labels (B, S)
    prefill: tokens (B, S) (+ frontend embeds)
    decode : tokens (B, 1) + positions (B,) (+ frontend embeds); the KV cache
             is produced separately via ``Model.cache_specs``.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode
        out["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        out["positions"] = jax.ShapeDtypeStruct((B,), i32)
    if cfg.num_image_tokens:
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, cfg.d_model), bf16)
    if cfg.encoder_layers:
        out["src_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.src_seq_len, cfg.d_model), bf16)
    return out
