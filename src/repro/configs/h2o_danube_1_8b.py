"""H2O-Danube-1.8B: llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; hf].  SWA (window 4096) bounds the KV cache, so the
long_500k decode cell runs with a ring cache.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    rope_theta=10_000.0,
    sliding_window=4096,
    microbatches=4,
    source="arXiv:2401.16818; hf",
))
