"""Gemma-3-4B: 34L, 5 local : 1 global attention pattern, 128k context.

[hf:google/gemma-3-1b-pt; unverified].  Local layers use a 1024-token
sliding window with rope_theta=10k; every 6th layer is global with
rope_theta=1M.  Only ~1/6 of layers keep a full-length cache, so the
long_500k decode cell runs (per-step cost is linear, cache is dominated
by the 5 global layers).  Gemma3 uses head_dim=256 (not d_model/heads).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    sliding_window=1024,
    global_every=6,
    rope_theta=10_000.0,
    rope_theta_global=1_000_000.0,
    tie_embeddings=True,
    microbatches=8,
    use_fsdp=True,
    source="hf:google/gemma-3-1b-pt; unverified",
))
