"""SeamlessM4T-medium backbone: 12L encoder + 12L decoder, MHA (kv=16).

[arXiv:2308.11596; hf].  The audio frontend is a stub per the assignment:
``input_specs`` supplies precomputed frame embeddings at d_model for the
encoder.  decode shapes lower the decoder step (self-cache + static
cross-attention KV from the encoder output).  long_500k is skipped
(enc-dec with full decoder self-attention).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,                # decoder layers
    encoder_layers=12,
    src_seq_len=1024,             # precomputed audio frames (stub)
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    microbatches=2,
    skip_shapes=("long_500k",),
    skip_reason="enc-dec with full decoder self-attention",
    source="arXiv:2308.11596; hf",
))
