"""StarCoder2-7B: 32L GQA + RoPE code model.  [arXiv:2402.19173; hf].

Deviation (recorded): the framework's FFN is uniformly SwiGLU (3
matrices); upstream StarCoder2 uses a 2-matrix GELU MLP, so our param
count is ~10.1B vs 7.2B upstream at the assigned d_ff=18432.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    vocab_size=49152,
    rope_theta=1_000_000.0,
    microbatches=8,
    use_fsdp=True,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention (assigned card: GQA+RoPE, no window)",
    source="arXiv:2402.19173; hf",
))
