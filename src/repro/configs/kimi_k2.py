"""Kimi-K2-1T-A32B: 61L trillion-param MoE, 384 experts top-8 + 1 shared,
first layer dense.  [arXiv:2501.kimi2; unverified].

The assigned card specifies standard GQA (64H, kv=8), so we implement GQA
(not MLA) with head_dim=128.  d_ff=2048 is the per-expert width; the
single leading dense layer uses the public 18432 width.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,                    # per-expert FFN width
    dense_d_ff=18432,             # the single leading dense layer
    vocab_size=163840,
    rope_theta=50_000.0,
    num_experts=384,
    top_k=8,
    num_shared_experts=1,
    first_dense_layers=1,
    capacity_factor=1.25,
    # §Perf: FSDP expert all-gathers repeat per microbatch (fwd + bwd
    # under remat), so grad-accumulation depth trades activation memory
    # against collective bytes: mb 16 -> 4 cut the collective roofline
    # term 329s -> 110s; int8 weight-only quantized gathers (tested <5%
    # output error) cut it further to 63s.
    microbatches=4,
    expert_gather_dtype="int8",
    use_fsdp=True,
    use_pod_fsdp=True,
    optimizer="adafactor",
    skip_shapes=("long_500k",),
    skip_reason="pure full attention",
    source="arXiv:2501.kimi2; unverified",
))
