"""The paper's own 'architecture': the pPython collective benchmark matrix.

pPython Performance Study (Byun et al., 2023) benchmarks point-to-point,
aggregation, and broadcast at per-process message sizes {8 B, 8 KB, 8 MB}
over 2..768 ranks.  We register the sweep here so the benchmark harness
and the dry-run can treat the paper's experiments as first-class configs.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class CollectiveBenchConfig:
    name: str = "ppython-collectives"
    # per-process message sizes, bytes (paper Figs 5/7)
    message_sizes: Tuple[int, ...] = (8, 8 * 1024, 8 * 1024 * 1024)
    # p2p sweep, bytes (paper Fig 3: 16 B .. 1 GB; we stop at 64 MB on CPU)
    p2p_sizes: Tuple[int, ...] = tuple(16 * 4 ** i for i in range(13))
    # rank counts (paper: 2..768; real CPU runs use <=32 virtual devices,
    # 256/512 are modeled via the roofline terms)
    measured_ranks: Tuple[int, ...] = (2, 4, 8, 16, 32)
    modeled_ranks: Tuple[int, ...] = (64, 128, 256, 512, 768)
    # paper's node boundary: 48 ranks/node; ours: 256 chips/pod
    ranks_per_node: int = 48
    dtype: str = "uint8"


CONFIG = CollectiveBenchConfig()
