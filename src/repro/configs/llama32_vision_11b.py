"""Llama-3.2-Vision-11B backbone: 40L, cross-attn image layers every 5th.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified].  Vision frontend is a
stub per the assignment: ``input_specs`` supplies precomputed patch
embeddings already projected to d_model.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    xattn_every=5,                 # 8 of 40 layers are cross-attention
    num_image_tokens=1601,         # 1 tile x (40x40+1) patches
    microbatches=8,
    use_fsdp=True,
    skip_shapes=("long_500k",),
    skip_reason="pure full attention; 500k decode cache is quadratic-history "
                "and the assignment says to skip pure full-attention archs",
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
))
