"""Trainer-level extension of the paper's study: gradient all-reduce via
flat native (mpi4py analogue) vs paper tree (agg+bcast) vs hierarchical
reduce-scatter (beyond-paper), plus int8-compressed cross-pod — all
driven through the public Communicator API exactly as train/steps.py
wires it (a CommSpec per mode, batch-axis topology).

Reports measured time on an 8-device (2 pod x 2 data x 2 model) virtual
mesh AND the HLO link bytes of each variant (from the roofline parser) —
the quantity that actually scales to 512 chips.
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import row, time_fn
from repro.comms import CommSpec, Communicator
from repro.roofline import hlo as hlo_lib


def main() -> None:
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    nbytes = 4 * 1024 * 1024
    x = jnp.ones((8, nbytes // 4 // 8), jnp.float32)
    spec = P(("pod", "data", "model"))

    for name in ("native", "tree", "hier", "hier_int8"):
        comm = Communicator(mesh, CommSpec.from_flag(name),
                            axes=("pod", "data"))
        f = jax.jit(comm.wrap(comm.allreduce, in_specs=(spec,),
                              out_specs=spec))
        us = time_fn(f, x)
        an = hlo_lib.analyze(f.lower(x).compile().as_text(), pod_size=4,
                             n_pods=2)
        row(f"gradex_{name}_4MiB", us,
            f"link={an['link_bytes']/2**20:.2f}MiB "
            f"dci={an['dci_link_bytes']/2**20:.2f}MiB")


if __name__ == "__main__":
    main()
