"""Deprecated shim over :mod:`repro.bench.sampling` / :mod:`repro.bench.hw`.

The benchmark implementations moved to ``src/repro/bench/cases.py``;
``time_fn`` and the v5e link constants stay importable from here for
one release so out-of-tree callers keep working.
"""
from __future__ import annotations

from typing import Callable

from repro.bench.hw import DCI_BW, DCI_LAT, ICI_BW, ICI_LAT  # noqa: F401
from repro.bench.sampling import sample, stats_us


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of a jitted call."""
    return stats_us(sample(fn, *args, warmup=warmup, iters=iters))[
        "median_us"]


def row(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")
