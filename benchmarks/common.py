"""Shared benchmark utilities.  Every benchmark prints CSV rows:
``name,us_per_call,derived`` (derived = bandwidth GB/s or notes).
"""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-time (us) of a jitted call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> None:
    print(f"{name},{us:.1f},{derived}")


# v5e model constants for the modeled (256..768-rank) extension of the
# paper's sweep — CPU cannot measure those scales.
ICI_BW = 50e9      # B/s per chip (in-pod)
DCI_BW = 6.25e9    # B/s per chip (cross-pod)
ICI_LAT = 1e-6     # s per hop
DCI_LAT = 10e-6
