"""Benchmark harness — one benchmark per paper table/figure.

    Fig 2/3 (p2p bw/latency)      -> benchmarks.p2p
    Fig 5   (aggregation)         -> benchmarks.collective (agg_*)
    Fig 7   (broadcast init/opt)  -> benchmarks.collective (bcast_*)
    HPCC heritage (STREAM)        -> benchmarks.stream
    trainer-level grad exchange   -> benchmarks.grad_exchange
    roofline summary (§Roofline)  -> re-emitted from experiments/dryrun

Each sub-benchmark runs in its own subprocess with the virtual-device
count it needs (the parent stays at 1 device).  Output: CSV rows
``name,us_per_call,derived``.
"""
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)

SUITES = [
    ("benchmarks.p2p", 2),
    ("benchmarks.collective", 8),
    ("benchmarks.grad_exchange", 8),
    ("benchmarks.stream", 1),
]


def main() -> None:
    env_base = dict(os.environ)
    env_base["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT,
         env_base.get("PYTHONPATH", "")])
    print("name,us_per_call,derived")
    failures = []
    for mod, ndev in SUITES:
        env = dict(env_base)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
        r = subprocess.run([sys.executable, "-m", mod], env=env, cwd=ROOT)
        if r.returncode:
            failures.append(mod)
    # roofline summary re-emit (no timing — derived column only)
    try:
        sys.path.insert(0, os.path.join(ROOT, "src"))
        from repro.roofline import analysis
        rows = [r for c in analysis.load_cells() if (r := analysis.roofline_row(c))]
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
            dom = r["dominant"]
            print(f"roofline_{r['arch']}_{r['shape']},0.0,"
                  f"bound={dom};frac={r['roofline_fraction']:.4f};"
                  f"useful={r['useful_ratio']:.2f}")
    except Exception as e:  # noqa: BLE001
        print(f"roofline_summary,0.0,unavailable:{e}")
    if failures:
        print(f"FAILED_SUITES,{len(failures)},{';'.join(failures)}")
        sys.exit(1)


if __name__ == "__main__":
    main()
