"""Deprecated shim — the harness lives in ``repro.bench`` now.

    python -m repro.bench --out BENCH_ci.json     # or: repro-bench
    python -m repro.bench.compare RUN BASELINE    # regression gate

This wrapper keeps the historical entry point (``python benchmarks/
run.py``) working: it forwards its arguments to ``python -m
repro.bench`` (defaulting to the paper-faithful ``full`` profile, the
old behavior) and propagates the exit code — including failures from
the roofline re-emit, which the old harness swallowed behind a bare
``except Exception``.
"""
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)


def main() -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(ROOT, "src"), ROOT,
                    env.get("PYTHONPATH", "")) if p)
    argv = sys.argv[1:] or ["--profile", "full"]
    r = subprocess.run([sys.executable, "-m", "repro.bench", *argv],
                       env=env, cwd=ROOT)
    sys.exit(r.returncode)


if __name__ == "__main__":
    main()
