"""HPCC-heritage STREAM triad — thin shim over the registered ``stream``
case in :mod:`repro.bench.cases`; run the whole suite with
``python -m repro.bench``."""
import os

CASES = ("stream",)
NDEV = 1

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={NDEV}"


def main() -> None:
    from repro.bench.runner import print_csv, run_cases_inline
    print_csv(run_cases_inline(
        CASES, profile=os.environ.get("REPRO_BENCH_PROFILE", "full")))


if __name__ == "__main__":
    main()
