"""HPCC-heritage STREAM triad (the paper's earlier study [29] used the
HPC Challenge suite; we keep the local-bandwidth anchor): a = b + s*c."""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn


def main() -> None:
    for n in (1 << 20, 1 << 24):
        b = jnp.ones((n,), jnp.float32)
        c = jnp.ones((n,), jnp.float32)

        @jax.jit
        def triad(b, c):
            return b + 3.0 * c

        us = time_fn(triad, b, c)
        gb = 3 * 4 * n / (us * 1e-6) / 1e9
        row(f"stream_triad_{n}", us, f"{gb:.2f}GB/s")


if __name__ == "__main__":
    main()
