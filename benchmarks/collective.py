"""Paper Fig 5 (aggregation) + Fig 7 (broadcast).

Measured on virtual devices (2..8 ranks x {8 B, 8 KB, 8 MB} per-process)
through the public Communicator surface — one transport per paper
variant, selected from the registry:
  * agg:   'tree' (paper Fig 4 two-level binary gather)  vs  'native'
           all-gather (the mpi4py analogue);
  * bcast: 'serial' (paper 'initial'), 'tree' (paper 'optimized'),
           'native' replication.

Modeled to 256/512/768 ranks via the two-level cost model (rounds x
bytes / per-level bandwidth) — the paper's sweep reaches 768 ranks and
this container has 8 useful virtual devices, so large scales are modeled
exactly the way §Roofline models collectives.
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import DCI_BW, ICI_BW, row, time_fn
from repro.comms import Communicator
from repro.core import topology

SIZES = [8, 8 * 1024, 8 * 1024 * 1024]


def bench_ranks(n: int) -> None:
    mesh = jax.make_mesh((n,), ("r",))
    comms = {name: Communicator(mesh, name)
             for name in ("native", "tree", "serial")}
    spec = P("r")

    def jit_op(comm, op):
        def body(a):
            out = getattr(comm, op)(a)
            # reduce to a tiny per-rank value so timing isn't dominated
            # by materializing the gathered buffer
            return out.reshape(1, -1).mean(1, keepdims=True)
        return jax.jit(comm.wrap(body, in_specs=(spec,), out_specs=spec))

    for size in SIZES:
        elems = max(size // 4, 1)
        x = jnp.ones((n, elems), jnp.float32)
        row(f"agg_tree_r{n}_{size}B", time_fn(jit_op(comms["tree"],
                                                     "agg"), x))
        row(f"agg_native_r{n}_{size}B", time_fn(jit_op(comms["native"],
                                                       "agg"), x))
        for name in ("tree", "serial", "native"):
            row(f"bcast_{name}_r{n}_{size}B",
                time_fn(jit_op(comms[name], "bcast"), x))


def modeled() -> None:
    """Fig 7 extension: two-level model at pod scale (in-pod 256 ranks on
    ICI, cross-pod on DCI)."""
    for total in (64, 256, 512, 768):
        n_local = min(total, 256)
        n_global = max(total // 256, 1)
        for size in SIZES:
            t_tree = topology.two_level_cost(n_local, n_global, size,
                                             ICI_BW, DCI_BW, tree=True)
            t_serial = topology.two_level_cost(n_local, n_global, size,
                                               ICI_BW, DCI_BW, tree=False)
            row(f"bcast_model_tree_r{total}_{size}B", t_tree * 1e6,
                f"speedup={t_serial / max(t_tree, 1e-12):.1f}x")
            row(f"bcast_model_serial_r{total}_{size}B", t_serial * 1e6)


def main() -> None:
    n_dev = len(jax.devices())
    for n in (2, 4, 8):
        if n <= n_dev:
            bench_ranks(n)
    modeled()


if __name__ == "__main__":
    main()
