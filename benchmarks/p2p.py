"""Paper Fig 2/3: point-to-point bandwidth/latency sweep.

Measured: the public Communicator ``send``/``recv`` surface (pPython
SendMsg/RecvMsg over a scheduled ppermute hop) between two (virtual)
devices across message sizes — exactly the API the PGAS layer programs
against, per the OMB-Py discipline of benchmarking the user-visible
functions rather than private internals.  Modeled: v5e ICI (in-pod hop)
and DCI (cross-pod hop) times for the same sizes, the roofline-level
counterpart of the paper's local-vs-Lustre / TCP-vs-RoCE ablations.
"""
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from benchmarks.common import (DCI_BW, DCI_LAT, ICI_BW, ICI_LAT, row,
                               time_fn)
from repro.comms import Communicator


def main() -> None:
    mesh = jax.make_mesh((2,), ("x",))
    comm = Communicator(mesh)
    sizes = [16 * 4 ** i for i in range(12)]          # 16 B .. 64 MB

    for size in sizes:
        n = max(size // 4, 1)
        x = jnp.zeros((2, n), jnp.float32)

        def oneway(v):
            return comm.send(v, dst=1, src=0)

        def roundtrip(v):
            return comm.recv(comm.send(v, dst=1, src=0), 1, dst=0)

        spec = P("x")
        f = jax.jit(comm.wrap(oneway, in_specs=(spec,), out_specs=spec))
        g = jax.jit(comm.wrap(roundtrip, in_specs=(spec,), out_specs=spec))
        us = time_fn(f, x)
        bw = size / (us * 1e-6) / 1e9
        row(f"p2p_send_{size}B", us, f"{bw:.3f}GB/s")
        row(f"p2p_roundtrip_{size}B", time_fn(g, x))
        row(f"p2p_model_ici_{size}B", (ICI_LAT + size / ICI_BW) * 1e6,
            f"{size / (ICI_LAT + size / ICI_BW) / 1e9:.3f}GB/s")
        row(f"p2p_model_dci_{size}B", (DCI_LAT + size / DCI_BW) * 1e6,
            f"{size / (DCI_LAT + size / DCI_BW) / 1e9:.3f}GB/s")


if __name__ == "__main__":
    main()
