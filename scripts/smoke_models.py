"""Quick CPU smoke: every arch, reduced config: train loss + prefill + decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_configs, reduced
from repro.launch.mesh import make_local_mesh
from repro.models.model import Model

ARCHS = [a for a in list_configs()]


def run_one(name: str) -> None:
    cfg = reduced(get_config(name))
    mesh = make_local_mesh(1, 1)
    model = Model(cfg, mesh, q_chunk=8)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    n_leaves = len(jax.tree.leaves(params))
    B, S = 2, 16
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.num_image_tokens:
        batch["image_embeds"] = jnp.ones(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_layers:
        batch["src_embeds"] = jnp.ones(
            (B, cfg.src_seq_len, cfg.d_model), jnp.bfloat16)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert jnp.isfinite(loss), (name, loss)

    extras = {k: v for k, v in batch.items() if k.endswith("_embeds")}
    logits, cache = jax.jit(model.prefill)(params, batch["tokens"], extras)
    assert jnp.isfinite(logits).all(), name
    tok = batch["tokens"][:, :1]
    pos = jnp.full((B,), S, jnp.int32)
    lg2, cache2 = jax.jit(model.decode_step)(params, tok, pos, cache)
    assert lg2.shape == (B, 1, cfg.vocab_size), (name, lg2.shape)
    assert jnp.isfinite(lg2).all(), name
    print(f"OK {name:28s} loss={float(loss):8.4f} leaves={n_leaves}")


if __name__ == "__main__":
    names = sys.argv[1:] or ARCHS
    failures = []
    for n in names:
        try:
            run_one(n)
        except Exception as e:  # noqa: BLE001
            failures.append((n, repr(e)[:400]))
            print(f"FAIL {n}: {repr(e)[:400]}")
    sys.exit(1 if failures else 0)
