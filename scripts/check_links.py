#!/usr/bin/env python3
"""Markdown link checker for the repo's docs (CI docs job).

Walks every tracked ``*.md`` file and verifies that each relative link
target exists on disk.  External links (``http(s)://``, ``mailto:``)
and pure in-page anchors (``#...``) are skipped — CI must not depend on
network reachability.  Exit code 0 when every link resolves, 1 with a
``file:line`` listing otherwise.

    python scripts/check_links.py            # repo root inferred
    python scripts/check_links.py docs/ a.md # explicit roots/files
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# [text](target) — stop at the first ')' not preceded by an escape;
# good enough for the plain relative links these docs use.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:")
_EXCLUDE_DIRS = {".git", ".pytest_cache", "__pycache__", ".ruff_cache",
                 "node_modules", ".venv"}


def iter_markdown(roots: list[Path]):
    for root in roots:
        if root.is_file():
            yield root
            continue
        for p in sorted(root.rglob("*.md")):
            if not _EXCLUDE_DIRS.intersection(p.parts):
                yield p


def check_file(md: Path) -> list[str]:
    errors = []
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        for m in _LINK.finditer(line):
            target = m.group(1)
            if target.startswith(_SKIP_PREFIXES) or target.startswith("#"):
                continue
            path_part = target.split("#", 1)[0]
            if not path_part:
                continue
            resolved = (md.parent / path_part).resolve()
            if not resolved.exists():
                errors.append(f"{md}:{lineno}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    roots = ([Path(a) for a in argv]
             if argv else [Path(__file__).resolve().parent.parent])
    errors = []
    n = 0
    for md in iter_markdown(roots):
        n += 1
        errors.extend(check_file(md))
    for e in errors:
        print(e)
    print(f"checked {n} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
