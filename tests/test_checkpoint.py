"""Checkpoint durability: roundtrip, atomic LATEST, gc, async writer."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ck


@pytest.fixture()
def ckdir(tmp_path):
    return str(tmp_path / "ckpt")


def tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": (jnp.ones((4,), jnp.bfloat16),
                  {"c": jnp.asarray(3, jnp.int32)})}


def test_roundtrip_with_bf16(ckdir):
    t = tree()
    ck.save(ckdir, 7, t)
    assert ck.latest_step(ckdir) == 7
    out = ck.restore(ckdir, 7, t)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(t)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_latest_ignores_missing_dir(ckdir):
    ck.save(ckdir, 1, tree())
    ck.save(ckdir, 2, tree())
    shutil.rmtree(os.path.join(ckdir, "step_00000002"))
    # LATEST points at a deleted step -> falls back to newest valid
    assert ck.latest_step(ckdir) == 1


def test_gc_keeps_last(ckdir):
    for s in range(5):
        ck.save(ckdir, s, tree(), keep_last=2)
    assert sorted(ck.all_steps(ckdir)) == [3, 4]


def test_async_checkpointer_snapshots_before_donation(ckdir):
    """The async writer must survive the caller deleting device buffers
    right after save_async returns (donated-arg semantics)."""
    acp = ck.AsyncCheckpointer(ckdir)
    t = tree()
    acp.save_async(3, t)
    for leaf in jax.tree.leaves(t):
        leaf.delete()
    acp.wait()
    assert acp.last_saved == 3
    out = ck.restore(ckdir, 3, tree())
    assert float(jnp.sum(out["a"])) == 15.0


def test_latest_skips_truncated_leaf(ckdir):
    """A torn write (disk full, killed copy) that truncates a leaf file
    must not be offered for restore — failover falls back to the
    previous complete step."""
    ck.save(ckdir, 1, tree())
    ck.save(ckdir, 2, tree())
    with open(os.path.join(ckdir, "step_00000002", "leaf_0.npy"), "w"):
        pass  # truncate to zero bytes
    assert ck.latest_step(ckdir) == 1


def test_latest_skips_missing_leaf_and_bad_manifest(ckdir):
    ck.save(ckdir, 3, tree())
    ck.save(ckdir, 5, tree())
    ck.save(ckdir, 8, tree())
    os.remove(os.path.join(ckdir, "step_00000008", "leaf_1.npy"))
    with open(os.path.join(ckdir, "step_00000005", "manifest.json"),
              "w") as f:
        f.write("{not json")
    assert ck.latest_step(ckdir) == 3


def test_latest_ignores_inflight_tmp_dir(ckdir):
    """A crash mid-write leaves a .tmp dir; it must never be listed or
    restored (atomic rename is the commit point)."""
    ck.save(ckdir, 1, tree())
    os.makedirs(os.path.join(ckdir, "step_00000009.tmp0"))
    assert ck.all_steps(ckdir) == [1]
    assert ck.latest_step(ckdir) == 1


def test_latest_none_when_all_corrupt(ckdir):
    ck.save(ckdir, 4, tree())
    os.remove(os.path.join(ckdir, "step_00000004", "manifest.json"))
    assert ck.latest_step(ckdir) is None


def test_restore_with_mismatched_count_raises(ckdir):
    ck.save(ckdir, 0, tree())
    with pytest.raises(AssertionError):
        ck.restore(ckdir, 0, {"only": jnp.ones(3)})
