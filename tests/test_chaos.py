"""Chaos engineering: fault-injected transports, streamed Dmap
redistribution, and elastic recovery (shrink + grow) end to end."""
import pytest

from tests._subproc import run_py

# --------------------------------------------------------------- pure host


def test_redistribution_plan_invariants():
    from repro.core.dmap import Dmap, redistribution_plan

    n, shape = 4, (9, 5)
    src = Dmap(grid=(4, 1))
    dst = Dmap(grid=(2, 2), dist=(("bc", 2), ("b",)), overlap=(1, 0))
    counts, send_idx, recv_idx = redistribution_plan(src, dst, shape, n)
    assert counts.shape == (n, n)
    assert (counts >= 0).all()
    # every rank's send row holds exactly its counts' worth of real
    # (non-pad) indices, in-range for the OLD padded block
    import numpy as np
    old = int(np.prod(src.local_shape(shape)))
    new = int(np.prod(dst.local_shape(shape)))
    for i in range(n):
        row = send_idx[i]
        assert (row >= 0).sum() == counts[i].sum()
        assert row.max() < old
    for j in range(n):
        col = recv_idx[j]
        assert (col >= 0).sum() == counts[:, j].sum()
        assert col.max() < new
        real = col[col >= 0]
        assert len(set(real.tolist())) == len(real), "dup dest cells"
    # the plan is a pure function of its key (lru-cached)
    again = redistribution_plan(src, dst, shape, n)
    assert again[0] is counts


def test_fault_plan_schedule_is_deterministic():
    from repro.comms.faults import FaultPlan, HostEvent, maybe_wrap

    plan = FaultPlan(seed=7, delay_rate=0.3, drop_rate=0.3,
                     bitflip_rate=0.2,
                     events=(HostEvent(8, "restore", 8),
                             HostEvent(5, "lose", 4)))
    # events sort by step; schedule is stable across instances
    assert [e.step for e in plan.events] == [5, 8]
    other = FaultPlan(seed=7, delay_rate=0.3, drop_rate=0.3,
                      bitflip_rate=0.2)
    for seq in range(64):
        assert plan.op_faults("allreduce", seq) == \
            other.op_faults("allreduce", seq)
    with pytest.raises(ValueError):
        HostEvent(1, "explode", 4)
    with pytest.raises(ValueError):
        FaultPlan(drop_rate=1.5)
    # disarmed or op-fault-free plans add NO wrapper
    sentinel = object()
    assert maybe_wrap(sentinel, None) is sentinel
    assert maybe_wrap(sentinel, FaultPlan(events=plan.events)) is sentinel


# ----------------------------------------------------------- multi-device

CHAOS_EXACT = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.comms import Communicator, faults

mesh = Mesh(np.array(jax.devices()).reshape(8), ("d",))
x = jnp.arange(8.0 * 6).reshape(8, 6)
spec = P("d")
clean_comm = Communicator.for_mesh(mesh, "tree")
def ops(comm):
    out = {}
    out["allreduce"] = comm.run(comm.allreduce, x, in_specs=(spec,),
                                out_specs=spec)
    out["bcast"] = comm.run(comm.bcast, x, in_specs=(spec,),
                            out_specs=spec)
    out["reduce_scatter"] = comm.run(comm.reduce_scatter, x,
                                     in_specs=(spec,), out_specs=spec)
    return out
clean = ops(clean_comm)
plan = faults.FaultPlan(seed=1, delay_rate=0.4, drop_rate=0.4,
                        bitflip_rate=0.3, delay_iters=32, backoff_iters=8)
with faults.armed(plan):
    comm = Communicator.for_mesh(mesh, "tree")
    assert comm is not clean_comm, "armed plan must miss the comm cache"
    assert comm.fault_plan is plan
    chaotic = ops(comm)
log = faults.injection_log()
assert len(log) > 0, "rates this high must inject something"
assert any(e["failures"] for e in log), log
for k in clean:
    np.testing.assert_array_equal(np.asarray(chaotic[k]),
                                  np.asarray(clean[k]))
assert Communicator.for_mesh(mesh, "tree") is clean_comm
print("EXACT-OK faults=%d" % len(log))
"""


def test_chaos_transport_values_exact_under_faults():
    """Retried/corrupted attempts cost time, never correctness: every
    wrapped op's result is bit-exact with the unwrapped transport."""
    out = run_py(CHAOS_EXACT, ndev=8)
    assert "EXACT-OK" in out


REDIST = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core import dmat
from repro.core.dmap import Dmap

mesh = Mesh(np.array(jax.devices()).reshape(8), ("d",))
shape = (12, 10)
arr = jnp.arange(120, dtype=jnp.float32).reshape(shape)
pairs = [
    (Dmap(grid=(8, 1)), Dmap(grid=(1, 8))),
    (Dmap(grid=(8, 1)), Dmap(grid=(8, 1), dist=(("bc", 2), ("b",)))),
    (Dmap(grid=(4, 2), order="F"), Dmap(grid=(2, 4), dist=(("c",), ("b",)))),
    (Dmap(grid=(8, 1), overlap=(1, 0)), Dmap(grid=(2, 4))),
    (Dmap(grid=(2, 2), procs=(1, 3, 5, 7)), Dmap(grid=(8, 1))),
]
for src, dst in pairs:
    d = dmat.Dmat.from_global(arr, src, mesh)
    stream = d.redistribute(dst, method="stream")
    gather = d.redistribute(dst, method="gather")
    np.testing.assert_array_equal(np.asarray(stream.storage),
                                  np.asarray(gather.storage))
    np.testing.assert_array_equal(np.asarray(stream.to_global()),
                                  np.asarray(arr))
print("REDIST-OK", len(pairs))
"""


def test_streamed_redistribute_matches_gather_and_roundtrips():
    """Communicator.redistribute (one Alltoallv from the static plan)
    must agree with the composed-gather reference for block, cyclic,
    block-cyclic, overlapped, F-order, and procs-subset maps."""
    out = run_py(REDIST, ndev=8)
    assert "REDIST-OK 5" in out


ELASTIC = """
import jax, numpy as np, jax.numpy as jnp
from repro.train import elastic

m8 = elastic.grow_mesh(8, 4)
m4 = elastic.shrink_mesh(4, 4)
assert dict(m8.shape) == {"data": 2, "model": 4}
assert dict(m4.shape) == {"data": 1, "model": 4}
from jax.sharding import NamedSharding, PartitionSpec as P
tree = {"w": jnp.arange(16.0).reshape(8, 2)}
small = jax.device_put(tree, NamedSharding(m4, P("data")))
moved = elastic.live_redistribute(
    small, {"w": NamedSharding(m8, P("data"))})
assert moved["w"].sharding.mesh.devices.size == 8
np.testing.assert_array_equal(np.asarray(moved["w"]),
                              np.asarray(tree["w"]))
print("ELASTIC-OK")
"""


def test_grow_shrink_and_live_redistribute():
    out = run_py(ELASTIC, ndev=8)
    assert "ELASTIC-OK" in out


E2E = """
import numpy as np
from repro.comms import faults
from repro.configs.base import ShapeSpec, get_config, reduced
from repro.train.recovery import RecoveryConfig, RecoverySupervisor
from repro.train.trainer import TrainerConfig

cfg = reduced(get_config("h2o-danube-1.8b"), microbatches=2)
shape = ShapeSpec("chaos", "train", 16, 8)
STEPS = 10

def tcfg(ckpt):
    return TrainerConfig(total_steps=STEPS, checkpoint_every=2,
                         ckpt_dir=ckpt, grad_comms="tree", log_every=100)

ref = RecoverySupervisor(cfg, shape, tcfg("/tmp/chaos_t_ref"),
                         RecoveryConfig(model_width=4)).run(8)
assert ref["recoveries"] == 0

plan = faults.FaultPlan(seed=0, delay_rate=0.2, drop_rate=0.2,
                        bitflip_rate=0.1, delay_iters=32, backoff_iters=8,
                        events=(faults.HostEvent(5, faults.LOSE, 4),
                                faults.HostEvent(8, faults.RESTORE, 8)))
with faults.armed(plan):
    out = RecoverySupervisor(cfg, shape, tcfg("/tmp/chaos_t_run"),
                             RecoveryConfig(model_width=4)).run(8)
assert len(faults.injection_log()) > 0, "op faults must have fired"
assert out["recoveries"] == 2, out["events"]
assert [e["kind"] for e in out["events"]] == ["lose", "restore"]
assert len(out["detect_to_resume_s"]) == 2
assert all(t > 0 for t in out["detect_to_resume_s"])
ref_losses = [h["loss"] for h in ref["history"]]
run_losses = [h["loss"] for h in out["history"]]
assert [h["step"] for h in out["history"]] == list(range(STEPS))
np.testing.assert_allclose(run_losses, ref_losses, rtol=2e-2)
print("E2E-OK", ["%.4f" % x for x in run_losses])
"""


def test_chaos_training_reproduces_fault_free_trajectory():
    """The acceptance scenario: delays + retried drops + bit-flips on
    every collective of a tree grad exchange, a device loss at step 5
    (shrink remesh + checkpoint restore + replay) and a capacity
    restore at step 8 (grow remesh + LIVE state redistribution, no
    checkpoint round-trip) — and the merged loss trajectory still
    matches the fault-free run."""
    out = run_py("import shutil;"
                 "shutil.rmtree('/tmp/chaos_t_ref', ignore_errors=True);"
                 "shutil.rmtree('/tmp/chaos_t_run', ignore_errors=True)\n"
                 + E2E, ndev=8)
    assert "E2E-OK" in out
