"""All-to-all on the Communicator: five-transport equivalence against a
dense numpy oracle (multi-pod mesh), ragged alltoallv splits, and MoE
scatter-mode bitwise stability under transport swap (subprocesses, 8
virtual CPUs)."""
import pytest

from tests._subproc import run_py

TRANSPORTS = ("native", "tree", "serial", "hier", "hier_int8")

# out[r] block s == in[s] block r — the dense transpose oracle; blocks
# carry unique values so any mis-routed block is caught.
A2A = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import Communicator
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh({data}, {model}, pod={pod})
spec = P(tuple(mesh.axis_names))
n = 8
v = jnp.arange(n * n * 3, dtype=jnp.float32).reshape(n, n * 3) + 1
exp = np.transpose(np.asarray(v).reshape(n, n, 3), (1, 0, 2))

name = "{name}"
comm = Communicator(mesh, name)
out = comm.run(lambda a: comm.alltoall(a.reshape(n, 3)).reshape(1, -1),
               v, in_specs=(spec,), out_specs=spec)
got = np.asarray(out).reshape(n, n, 3)
if name == "hier_int8" and {pod}:       # cross-pod rounds are int8-lossy
    assert np.allclose(got, exp, rtol=0.02, atol=0.5), got - exp
else:                                    # pure data movement: bit-exact
    assert np.array_equal(got, exp), got - exp

# pytree payloads travel together (the MoE dispatch carries (x, leid))
tree = {{"x": v, "i": (v * 2).astype(jnp.int32)}}
pair = comm.run(
    lambda d: jax.tree.map(
        lambda l: comm.alltoall(l.reshape(n, -1)).reshape(1, -1), d),
    tree, in_specs=({{"x": spec, "i": spec}},),
    out_specs={{"x": spec, "i": spec}})
assert np.array_equal(np.asarray(pair["i"]).reshape(n, n, 3),
                      (exp * 2).astype(np.int32)), "int leaf"
print("OK")
"""


@pytest.mark.parametrize("name", TRANSPORTS)
def test_alltoall_matches_oracle_multi_pod(name):
    assert "OK" in run_py(A2A.format(name=name, data=2, model=2, pod=2))


@pytest.mark.parametrize("name", ("tree", "serial"))
def test_alltoall_matches_oracle_single_pod(name):
    assert "OK" in run_py(A2A.format(name=name, data=2, model=4, pod=0))


# alltoallv: asymmetric static count matrix, destination-ordered rows in,
# source-ordered rows out, zero-padded tails.
A2AV = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import Communicator
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(2, 2, pod=2)
spec = P(tuple(mesh.axis_names))
n = 8
counts = [[(i + 2 * j) % 4 for j in range(n)] for i in range(n)]
cm = np.asarray(counts)
S = int(cm.sum(1).max())
R = int(cm.sum(0).max())
x = jnp.arange(n * S * 2, dtype=jnp.float32).reshape(n, S * 2) + 1
xr = np.asarray(x).reshape(n, S, 2)
exp = np.zeros((n, R, 2), np.float32)
for r in range(n):
    off_out = 0
    for s in range(n):
        c = cm[s, r]
        off_in = int(cm[s, :r].sum())
        exp[r, off_out:off_out + c] = xr[s, off_in:off_in + c]
        off_out += c

name = "{name}"
comm = Communicator(mesh, name)
out = comm.run(
    lambda a: comm.alltoallv(a.reshape(S, 2), counts).reshape(1, -1),
    x, in_specs=(spec,), out_specs=spec)
got = np.asarray(out).reshape(n, R, 2)
if name == "hier_int8":
    assert np.allclose(got, exp, rtol=0.02, atol=2.0), got - exp
else:
    assert np.array_equal(got, exp), got - exp
print("OK")
"""


@pytest.mark.parametrize("name", TRANSPORTS)
def test_alltoallv_ragged_splits(name):
    assert "OK" in run_py(A2AV.format(name=name))


# MoE scatter mode: the exchange is pure data movement, so swapping the
# transport must not change a single bit of the output.
MOE_SWAP = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_local_mesh
from repro.models.moe import moe_ffn, moe_ffn_reference, moe_init
key = jax.random.PRNGKey(2)
B, T, D, F, E, k = 2, 8, 16, 32, 8, 2
p = moe_init(key, D, F, E)
x = jax.random.normal(key, (B, T, D), jnp.bfloat16)
mesh = make_local_mesh(2, 4)
y_ref, _ = moe_ffn_reference(p, x, top_k=k, num_experts=E)
ys = {}
for t in ("native", "tree", "serial", "hier", "hier_int8"):
    y, aux = moe_ffn(p, x, top_k=k, num_experts=E,
                     capacity_factor=float(E), mesh=mesh,
                     batch_axes=("data",), mode="scatter", comm=t)
    ys[t] = np.asarray(y, np.float32)
    assert np.allclose(ys[t], np.asarray(y_ref, np.float32), atol=0.05), t
for t, y in ys.items():
    assert np.array_equal(y, ys["native"]), f"{t} not bitwise-stable"
# replicated (decode) combine rides the same Communicator
y1, _ = moe_ffn(p, x[:, :1], top_k=k, num_experts=E, capacity_factor=4.0,
                mesh=mesh, batch_axes=("data",), mode="replicated",
                comm="tree")
y2, _ = moe_ffn(p, x[:, :1], top_k=k, num_experts=E, capacity_factor=4.0,
                mesh=mesh, batch_axes=("data",), mode="replicated",
                comm="native")
assert np.allclose(np.asarray(y1, np.float32), np.asarray(y2, np.float32),
                   atol=1e-3)
print("OK")
"""


def test_moe_scatter_bitwise_stable_under_transport_swap():
    assert "OK" in run_py(MOE_SWAP, ndev=8)


def test_moe_has_no_direct_lax_all_to_all():
    """Acceptance criterion: MoE dispatch goes through the Communicator,
    never through raw XLA collectives."""
    import inspect

    from repro.models import moe

    src = inspect.getsource(moe)
    assert "lax.all_to_all(" not in src
    assert "lax.psum(" not in src


def test_commspec_carries_alltoall():
    from repro.comms import CommSpec

    assert CommSpec.from_flag("tree").alltoall == "tree"
    assert CommSpec().alltoall == "native"
