"""Test-suite bootstrap.

If `hypothesis` is installed, it is used as-is.  If not (minimal
containers), the deterministic fallback in tests/_hypothesis_fallback.py
is registered under the ``hypothesis`` name BEFORE test modules import
it, so the property-test modules still collect and run.  Install the
real package via requirements-dev.txt for genuine input-space search.
"""
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (ROOT, os.path.join(ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

try:
    import hypothesis  # noqa: F401
except ImportError:
    from tests import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback
    _hypothesis_fallback.strategies = _hypothesis_fallback
