"""SSM invariants: chunkwise == sequential, state continuity across
splits (the property chunked prefill + decode rely on)."""
import jax
import jax.numpy as jnp
import pytest

from repro.models.ssm import (mlstm_forward, mlstm_init, slstm_forward,
                              slstm_init, ssm_forward, ssm_init)

KEY = jax.random.PRNGKey(1)


def test_mlstm_chunkwise_equals_sequential():
    B, S, D, H, dh = 2, 64, 32, 4, 8
    p = mlstm_init(KEY, D, H, dh, dtype=jnp.float32)
    x = jax.random.normal(KEY, (B, S, D))
    y_seq, st_seq = mlstm_forward(p, x, None, heads=H, dh=dh, chunk=1)
    y_chk, st_chk = mlstm_forward(p, x, None, heads=H, dh=dh, chunk=16)
    assert jnp.allclose(y_seq, y_chk, atol=1e-4)
    assert jnp.allclose(st_seq[0], st_chk[0], atol=1e-4)


@pytest.mark.parametrize("fwd,init", [(mlstm_forward, mlstm_init),
                                      (slstm_forward, slstm_init)])
def test_xlstm_state_continuity(fwd, init):
    """forward(full) == forward(first half) then forward(second half)."""
    B, S, D, H, dh = 2, 32, 16, 2, 8
    p = init(KEY, D, H, dh, dtype=jnp.float32)
    x = jax.random.normal(KEY, (B, S, D))
    y_full, _ = fwd(p, x, None, heads=H, dh=dh)
    y1, st = fwd(p, x[:, :S // 2], None, heads=H, dh=dh)
    y2, _ = fwd(p, x[:, S // 2:], st, heads=H, dh=dh)
    y_split = jnp.concatenate([y1, y2], axis=1)
    assert jnp.allclose(y_full, y_split, atol=1e-4)


def test_selective_ssm_state_continuity():
    B, S, D, di, st_n = 2, 32, 16, 24, 4
    p = ssm_init(KEY, D, di, st_n, dtype=jnp.float32)
    x = jax.random.normal(KEY, (B, S, D))
    y_full, _ = ssm_forward(p, x, None, d_inner=di, state=st_n, chunk=8)
    y1, st = ssm_forward(p, x[:, :16], None, d_inner=di, state=st_n, chunk=8)
    y2, _ = ssm_forward(p, x[:, 16:], st, d_inner=di, state=st_n, chunk=8)
    assert jnp.allclose(y_full, jnp.concatenate([y1, y2], 1), atol=1e-4)


def test_ssm_decode_steps_match_parallel():
    """Step-by-step (decode) == one parallel pass (prefill)."""
    B, S, D, di, st_n = 1, 8, 16, 24, 4
    p = ssm_init(KEY, D, di, st_n, dtype=jnp.float32)
    x = jax.random.normal(KEY, (B, S, D))
    y_par, _ = ssm_forward(p, x, None, d_inner=di, state=st_n)
    st = None
    ys = []
    for t in range(S):
        y, st = ssm_forward(p, x[:, t:t + 1], st, d_inner=di, state=st_n)
        ys.append(y)
    assert jnp.allclose(y_par, jnp.concatenate(ys, 1), atol=1e-4)
