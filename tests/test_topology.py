"""Schedule properties for the paper's tree algorithms."""
import math

from hypothesis import given, strategies as st

from repro.core import topology


@given(st.integers(2, 64), st.integers(0, 63))
def test_tree_bcast_covers_all(n, root):
    root %= n
    have = {root}
    for rnd in topology.tree_bcast_rounds(n, root):
        for src, dst in rnd:
            assert src in have, "sender must already hold the data"
            assert dst not in have, "receivers receive exactly once"
            have.add(dst)
    assert have == set(range(n))


@given(st.integers(2, 64))
def test_tree_bcast_round_count(n):
    assert len(topology.tree_bcast_rounds(n)) == math.ceil(math.log2(n))


@given(st.integers(2, 64), st.integers(0, 63))
def test_serial_bcast(n, root):
    root %= n
    rounds = topology.serial_bcast_rounds(n, root)
    assert len(rounds) == n - 1                      # the Fig 7 bottleneck
    assert all(len(r) == 1 and r[0][0] == root for r in rounds)
    assert {d for r in rounds for _, d in r} == set(range(n)) - {root}


@given(st.integers(2, 64))
def test_tree_gather_delivers_to_root(n):
    """Every rank's block reaches rank 0 through a binary tree."""
    holds = {i: {i} for i in range(n)}
    for rnd in topology.tree_gather_rounds(n):
        for src, dst in rnd:
            holds[dst] |= holds[src]
    assert holds[0] == set(range(n))


def test_two_level_cost_monotone():
    fast = topology.two_level_cost(256, 2, 8 << 20, 50e9, 6.25e9, tree=True)
    slow = topology.two_level_cost(256, 2, 8 << 20, 50e9, 6.25e9, tree=False)
    assert fast < slow
