"""Optimizer unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim.optimizer import (OptimizerConfig, adafactor_init,
                                   adafactor_update, adamw_init,
                                   adamw_update, clip_by_global_norm,
                                   global_norm, opt_init, opt_pspecs,
                                   opt_update, warmup_cosine)


def test_adamw_first_step_direction():
    """After one step from zero state, AdamW moves against the gradient
    sign with magnitude ~lr (bias-corrected)."""
    cfg = OptimizerConfig(peak_lr=1e-2, warmup_steps=0, weight_decay=0.0)
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.array([1.0, -1.0, 2.0, -0.5])}
    st_ = adamw_init(p)
    p2, _ = adamw_update(cfg, g, st_, p, jnp.asarray(1e-2))
    step = np.asarray(p["w"] - p2["w"])
    assert np.all(np.sign(step) == np.sign(np.asarray(g["w"])))
    assert np.allclose(np.abs(step), 1e-2, rtol=1e-3)


def test_adafactor_factored_state_shapes():
    p = {"w": jnp.ones((6, 8)), "b": jnp.ones((8,))}
    s = adafactor_init(p)
    assert s["slots"]["w"]["vr"].shape == (6,)
    assert s["slots"]["w"]["vc"].shape == (8,)
    assert s["slots"]["b"]["v"].shape == (8,)


def test_adafactor_decreases_loss():
    cfg = OptimizerConfig(name="adafactor", peak_lr=0.1, warmup_steps=0,
                          weight_decay=0.0)
    w = {"w": jnp.array([[2.0, -3.0], [1.0, 4.0]])}
    state = opt_init(cfg, w)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    start = float(loss(w))
    for step in range(20):
        g = jax.grad(loss)(w)
        w, state, lr = opt_update(cfg, g, state, w, jnp.asarray(step))
        # warmup_steps=0: cosine starts at peak, barely decayed by step 20
        assert abs(float(lr) - cfg.peak_lr) < 1e-4 * cfg.peak_lr
    assert float(loss(w)) < start / 3


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(-100, 100), min_size=1, max_size=8),
       st.floats(0.01, 10))
def test_clip_by_global_norm_property(vals, max_norm):
    tree = {"a": jnp.asarray(vals, jnp.float32)}
    clipped, pre = clip_by_global_norm(tree, max_norm)
    post = float(global_norm(clipped))
    assert post <= max_norm * 1.01 + 1e-5
    if float(pre) <= max_norm:   # no-op below the threshold
        np.testing.assert_allclose(np.asarray(clipped["a"]),
                                   np.asarray(tree["a"]), rtol=1e-5)


def test_schedule_shape():
    cfg = OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(warmup_cosine(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0 and lrs[1] == 0.5 and abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0 and lrs[4] < 0.01


def test_opt_pspecs_mirror_params():
    from jax.sharding import PartitionSpec as P
    cfg = OptimizerConfig(name="adafactor")
    params = {"w": jnp.ones((4, 8))}
    specs = {"w": P("data", "model")}
    out = opt_pspecs(cfg, specs, params)
    assert out["slots"]["w"]["vr"] == P("data")
    assert out["slots"]["w"]["vc"] == P("model")
