"""Multi-device equivalence of the paper's collectives vs native XLA,
run in subprocesses with virtual devices (single- and multi-pod meshes)."""
from tests._subproc import run_py

CODE = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms.compat import shard_map
from repro.comms.topology import Topology
from repro.core import collectives as coll
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh({data}, {model}, pod={pod})
axes = tuple(mesh.axis_names)
topo = Topology.from_mesh(mesh)
pod, in_axes = topo.pod_axis, topo.in_axes
v = jnp.arange(8 * 5, dtype=jnp.float32).reshape(8, 5) + 1
sm = lambda f: shard_map(f, mesh=mesh, in_specs=(P(axes),),
                         out_specs=P(axes))
flat = sm(lambda a: jax.lax.psum(a, axes))(v)
tree = sm(lambda a: coll.tree_allreduce_local(a, pod_axis=pod, in_axes=in_axes))(v)
hier = sm(lambda a: coll.hier_allreduce_local(a, pod_axis=pod, in_axes=in_axes))(v)
# int8 cross-pod wire compression is now a layer over the same schedule
from repro.comms import compression as cx
def hier8_body(a):
    with cx.compressing(cx.LEGACY_INT8, (pod,) if pod else ()):
        return coll.hier_allreduce_local(a, pod_axis=pod, in_axes=in_axes)
hier8 = sm(hier8_body)(v)
assert np.allclose(flat, tree), "tree != psum"
assert np.allclose(flat, hier), "hier != psum"
assert np.allclose(flat, hier8, rtol=0.02, atol=0.5), "hier int8 too lossy"
exp = np.tile(np.asarray(v[:1]), (8, 1))
for kind in (True, False):
    b = sm(lambda a, k=kind: coll.two_level_bcast(
        a, pod_axis=pod, in_axes=in_axes, tree=k))(v)
    assert np.allclose(b, exp), ("bcast", kind)
# agg: leader-only concat gather
g = sm(lambda a: coll.two_level_agg(a.reshape(-1), pod_axis=pod,
                                     in_axes=in_axes).reshape(1, -1))(v)
got = np.asarray(g).reshape(8, 8, 5)[0]
assert np.allclose(got, np.asarray(v)), "agg leader mismatch"
print("OK")
"""


def test_single_pod_mesh():
    assert "OK" in run_py(CODE.format(data=2, model=4, pod=0))


def test_multi_pod_mesh():
    assert "OK" in run_py(CODE.format(data=2, model=2, pod=2))


def test_dmat_roundtrip_agg_redistribute():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import Dmap, Dmat
from repro.launch.mesh import make_local_mesh
mesh = make_local_mesh(2, 4)
x = jnp.arange(12 * 7, dtype=jnp.float32).reshape(12, 7)
for dm in (Dmap(grid=(4, 2)), Dmap(grid=(2, 4), dist=(("c",), ("bc", 2))),
           Dmap(grid=(2, 2), procs=(1, 3, 5, 7)), Dmap(grid=(4, 2), overlap=(1, 0))):
    d = Dmat.from_global(x, dm, mesh)
    assert np.allclose(d.to_global(), x)
    assert np.allclose(d.redistribute(Dmap(grid=(8, 1))).to_global(), x)
    agg = jax.jit(lambda s, d=d: Dmat(s, d.dmap, d.shape, d.mesh).agg())(d.storage)
    assert np.allclose(agg, x)
# paper semantics: maps off -> plain numpy-like arrays
from repro.core import zeros
assert isinstance(zeros((3, 3)), jax.Array)
print("OK")
"""
    assert "OK" in run_py(code)
