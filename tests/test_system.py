"""End-to-end behaviour tests: training converges on structured data,
fault tolerance (failure injection -> restart), elastic re-mesh, serving,
and the HLO roofline parser — run on virtual-device subprocesses where a
mesh is needed.
"""
import jax
import jax.numpy as jnp
import numpy as np

from tests._subproc import run_py


def test_train_loss_decreases_and_restart_matches():
    code = """
import os, shutil, numpy as np, jax
from repro.configs.base import get_config, reduced, ShapeSpec
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import Trainer, TrainerConfig
cfg = reduced(get_config("h2o-danube-1.8b"), microbatches=2)
shape = ShapeSpec("tiny", "train", 64, 16)
mesh = make_local_mesh(2, 4)
d = "/tmp/repro_sys_ckpt"
shutil.rmtree(d, ignore_errors=True)
t = Trainer(cfg, shape, mesh, TrainerConfig(total_steps=14, checkpoint_every=5,
            ckpt_dir=d, log_every=100, failure_at=11))
try:
    t.run(resume=False)
    raise SystemExit("failure not injected")
except RuntimeError:
    pass
t2 = Trainer(cfg, shape, mesh, TrainerConfig(total_steps=14, checkpoint_every=5,
             ckpt_dir=d, log_every=100))
out = t2.run(resume=True)
steps = [h["step"] for h in out["history"]]
assert steps[0] == 11 and steps[-1] == 13, steps
losses = [h["loss"] for h in out["history"]]
assert np.isfinite(losses).all()
# synthetic data has learnable structure: loss should be below init ~ln(V)
assert out["final_loss"] < 6.4, out["final_loss"]
# elastic: restore under a smaller mesh with new shardings
from repro.train import elastic, steps as steps_lib
from repro.optim.optimizer import OptimizerConfig
from repro.models.model import Model
small = elastic.shrink_mesh(4, 4)
m2 = Model(cfg, small)
b2 = steps_lib.sharding_bundle(m2, OptimizerConfig(), shape)
step, tree = elastic.remesh_restore(d,
    {"params": b2["abstract_params"], "opt": b2["abstract_opt"]},
    {"params": b2["params"], "opt": b2["opt"]})
assert step == 13
print("OK")
"""
    assert "OK" in run_py(code, ndev=8, timeout=560)


def test_serving_engine_batched():
    code = """
import numpy as np, jax
from repro.configs.base import get_config, reduced
from repro.launch.mesh import make_local_mesh
from repro.models.model import Model
from repro.serve.engine import Engine, Request
cfg = reduced(get_config("gemma3-4b"))
mesh = make_local_mesh(1, 1)
eng = Engine(cfg, mesh, slots=3, max_len=64)
params = Model(cfg, mesh).init(jax.random.PRNGKey(0))
eng.load(params)
reqs = [Request(rid=i, prompt=(np.arange(4 + 3 * i) % cfg.vocab_size),
                max_new_tokens=5) for i in range(5)]
res = eng.run_to_completion(reqs)
assert sorted(res) == [0, 1, 2, 3, 4]
assert all(len(v) == 5 for v in res.values())
# greedy decode must be independent of batch composition: single-request
# engine reproduces the batched tokens
eng2 = Engine(cfg, mesh, slots=1, max_len=64)
eng2.load(params)
solo = eng2.run_to_completion([Request(rid=0,
        prompt=(np.arange(4) % cfg.vocab_size), max_new_tokens=5)])
assert solo[0] == res[0], (solo[0], res[0])
print("OK")
"""
    assert "OK" in run_py(code, ndev=1, timeout=560)


def test_hlo_parser_trip_counts():
    """The roofline analyzer must multiply loop bodies by trip counts."""
    from repro.roofline import hlo as hlo_lib

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), ()
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out.sum()

    xs = jnp.ones((64, 32), jnp.float32)
    ws = jnp.ones((32, 32), jnp.float32)
    c = jax.jit(f).lower(xs, ws).compile()
    an = hlo_lib.analyze(c.as_text())
    per_iter = 2 * 64 * 32 * 32
    assert an["dot_flops"] == 7 * per_iter, an["dot_flops"]
    assert any(t == 7 for _, t in an["loops"])
    ca = c.cost_analysis()
    if isinstance(ca, list):              # older jaxlib: one dict per device
        ca = ca[0] if ca else {}
    raw = ca.get("flops", 0)
    assert raw < an["dot_flops"]          # raw undercounts loops


def test_grad_comms_modes_equivalent():
    code = """
import shutil, numpy as np
from repro.configs.base import get_config, reduced, ShapeSpec
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import Trainer, TrainerConfig
cfg = reduced(get_config("h2o-danube-1.8b"), microbatches=2)
shape = ShapeSpec("tiny", "train", 32, 16)
mesh = make_local_mesh(2, 2, pod=2)
losses = {}
for mode in ("auto", "native", "tree", "serial", "hier", "hier_int8"):
    shutil.rmtree("/tmp/repro_gc_ckpt", ignore_errors=True)
    t = Trainer(cfg, shape, mesh, TrainerConfig(total_steps=3,
        checkpoint_every=100, ckpt_dir="/tmp/repro_gc_ckpt",
        grad_comms=mode, log_every=100))
    losses[mode] = [h["loss"] for h in t.run(resume=False)["history"]]
a = losses["auto"]
for mode in ("native", "tree", "serial", "hier"):
    assert np.allclose(a, losses[mode], rtol=2e-2), (mode, a, losses[mode])
assert np.allclose(a, losses["hier_int8"], rtol=8e-2)
print("OK")
"""
    assert "OK" in run_py(code, ndev=8, timeout=560)


def test_grad_comms_overlap_modes_equivalent():
    """The double-buffered overlap pipeline reorders the exchange but
    must not change what is exchanged: losses match the GSPMD baseline,
    and the lr metric surfaces the real (warmup) schedule value."""
    code = """
import shutil, numpy as np
from repro.configs.base import get_config, reduced, ShapeSpec
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import Trainer, TrainerConfig
cfg = reduced(get_config("h2o-danube-1.8b"), microbatches=2)
shape = ShapeSpec("tiny", "train", 32, 16)
mesh = make_local_mesh(2, 2, pod=2)
hist = {}
for mode in ("auto", "native_overlap", "tree_overlap", "hier_overlap"):
    shutil.rmtree("/tmp/repro_gco_ckpt", ignore_errors=True)
    t = Trainer(cfg, shape, mesh, TrainerConfig(total_steps=3,
        checkpoint_every=100, ckpt_dir="/tmp/repro_gco_ckpt",
        grad_comms=mode, log_every=100))
    hist[mode] = t.run(resume=False)["history"]
a = [h["loss"] for h in hist["auto"]]
for mode in ("native_overlap", "tree_overlap", "hier_overlap"):
    m = [h["loss"] for h in hist[mode]]
    assert np.allclose(a, m, rtol=2e-2), (mode, a, m)
# lr metric: step 0 sits at warmup start (0), then strictly increases,
# identically across exchange modes
for mode, rows in hist.items():
    lrs = [h["lr"] for h in rows]
    assert lrs[0] == 0.0 and lrs[2] > lrs[1] > 0.0, (mode, lrs)
    assert np.allclose(lrs, [h["lr"] for h in hist["auto"]]), (mode, lrs)
print("OK")
"""
    assert "OK" in run_py(code, ndev=8, timeout=560)
