"""Per-arch reduced smoke tests + prefill/decode consistency.

Every assigned architecture instantiates a reduced same-family config and
runs train loss + chunked prefill + one decode step on CPU, asserting
shapes and finiteness.  The consistency test checks the serving
invariant: [prefill(N); decode x k] logits == prefill(N+k) logits.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_config, list_configs, reduced
from repro.launch.mesh import make_local_mesh
from repro.models.model import Model

ARCHS = list_configs()


def _build(name):
    cfg = reduced(get_config(name))
    mesh = make_local_mesh(1, 1)
    model = Model(cfg, mesh, q_chunk=8)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _batch(cfg, B, S, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.num_image_tokens:
        batch["image_embeds"] = jnp.ones(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.encoder_layers:
        batch["src_embeds"] = jnp.ones(
            (B, cfg.src_seq_len, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_arch_smoke(name):
    cfg, model, params = _build(name)
    B, S = 2, 16
    batch = _batch(cfg, B, S, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert jnp.isfinite(loss), name
    extras = {k: v for k, v in batch.items() if k.endswith("_embeds")}
    logits, cache = jax.jit(model.prefill)(params, batch["tokens"], extras)
    assert jnp.isfinite(logits).all()
    lg2, cache2 = jax.jit(model.decode_step)(
        params, batch["tokens"][:, :1], jnp.full((B,), S, jnp.int32), cache)
    assert lg2.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(lg2).all()
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("name", ["h2o-danube-1.8b", "gemma3-4b",
                                  "xlstm-350m", "hymba-1.5b"])
def test_prefill_decode_consistency(name):
    """Decoding token-by-token after a prefill must reproduce the logits
    of prefilling the longer prompt (exactness of ring caches + states)."""
    cfg, model, params = _build(name)
    B, S, K = 1, 16, 4
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S + K), 0,
                                cfg.vocab_size)
    # ground truth: prefill the full prompt
    lg_full, _ = jax.jit(model.prefill)(params, tokens, {})
    # prefill S (with decode headroom), then decode K tokens one at a time
    lg, cache = jax.jit(lambda p, t: model.prefill(p, t, {}, max_len=S + K)
                        )(params, tokens[:, :S])
    for i in range(K):
        lg, cache = jax.jit(model.decode_step)(
            params, tokens[:, S + i:S + i + 1],
            jnp.full((B,), S + i, jnp.int32), cache)
    a = jnp.asarray(lg[:, -1], jnp.float32)
    b = jnp.asarray(lg_full[:, -1], jnp.float32)
    assert jnp.max(jnp.abs(a - b)) < 0.15, (name, float(jnp.max(jnp.abs(a - b))))


def test_param_count_scale():
    """Full-size param counts are in the advertised ballpark."""
    # vision tower is stubbed per the assignment, backbone ~9.8B of 11B
    assert 9e9 < get_config("llama-3.2-vision-11b").param_count() < 13e9
    assert 0.9e12 < get_config("kimi-k2-1t-a32b").param_count() < 1.2e12
    # uniform-SwiGLU FFN inflates vs upstream's 2-matrix GELU MLP
    # (see configs/starcoder2_7b.py docstring)
    assert 8e9 < get_config("starcoder2-7b").param_count() < 11e9
    active = get_config("kimi-k2-1t-a32b").param_count(active_only=True)
    assert 20e9 < active < 45e9
