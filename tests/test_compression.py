"""The composable compressed-comms layer (repro/comms/compression.py):
quantize/dequantize properties, wire accounting, spec/flag validation,
and multi-device equivalence of compressed transports — including the
bitwise ``hier_int8`` alias-vs-legacy oracle."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.comms import CommSpec, CompressionSpec
from repro.comms import compression as cx
from tests._subproc import run_py

# --------------------------------------------------------------- qdq props


@st.composite
def payloads(draw):
    r = draw(st.integers(min_value=1, max_value=3))
    m = draw(st.integers(min_value=1, max_value=48))
    vals = draw(st.lists(st.floats(min_value=-100.0, max_value=100.0),
                         min_size=r * m, max_size=r * m))
    dtype = draw(st.sampled_from(["int8", "fp8", "int4"]))
    block = draw(st.sampled_from([None, 2, 8, 16]))
    return np.asarray(vals, np.float32).reshape(r, m), dtype, block


#: elementwise round-trip error bound, as a fraction of the GLOBAL amax
#: (per-block scales only tighten it): int rounding loses <= scale/2 =
#: amax/(2*qmax); e4m3 has 3 mantissa bits (rel err <= 2^-4)
_ERR_FRAC = {"int8": 0.5 / 127.0, "int4": 0.5 / 7.0, "fp8": 1.0 / 16.0}


@settings(max_examples=60, deadline=None)
@given(payloads())
def test_quantize_roundtrip_error_bounded(case):
    import jax.numpy as jnp
    x, dtype, block = case
    spec = CompressionSpec(dtype=dtype, block=block)
    q, s = cx.quantize_rows(jnp.asarray(x), spec)
    out = np.asarray(cx.dequantize_rows(q, s, spec, x.shape[1], jnp.float32))
    assert out.shape == x.shape
    amax = float(np.max(np.abs(x)))
    tol = amax * _ERR_FRAC[dtype] * 1.01 + 1e-6
    assert float(np.max(np.abs(out - x))) <= tol, (dtype, block)
    # qdq is the same projection through the 1-row path
    full = np.asarray(cx.qdq(jnp.asarray(x.reshape(-1)), spec))
    srt = np.asarray(cx.qdq(jnp.asarray(x.reshape(-1)), spec))
    np.testing.assert_array_equal(full, srt)  # deterministic
    # wire accounting matches what was actually materialized:
    # quantized payload bytes + one f32 scale per block
    payload = q.shape[0] * q.shape[1] * q.dtype.itemsize
    scales = s.shape[0] * s.shape[1] * 4
    assert payload + scales == x.shape[0] * spec.wire_bytes(x.shape[1])


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(min_value=-7, max_value=7),
                min_size=2, max_size=32).map(
                    lambda v: v[:len(v) - len(v) % 2]))
def test_int4_pack_unpack_roundtrip(vals):
    import jax.numpy as jnp
    k = jnp.asarray(vals, jnp.int8).reshape(1, -1)
    p = cx._pack_int4(k)
    assert p.shape == (1, k.shape[1] // 2) and p.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(cx._unpack_int4(p)),
                                  np.asarray(k))


def test_qdq_preserves_zeros_and_ints():
    import jax.numpy as jnp
    spec = CompressionSpec(dtype="int8")
    z = jnp.zeros((17,), jnp.float32)
    np.testing.assert_array_equal(np.asarray(cx.qdq(z, spec)), np.asarray(z))
    ints = jnp.arange(6, dtype=jnp.int32)     # integer payloads pass through
    assert cx.qdq(ints, spec) is ints


# ---------------------------------------------------------- wire accounting


def test_wire_ratio_acceptance_floors():
    n = (8 << 20) // 4                        # the full profile's largest
    assert CompressionSpec(dtype="int8").ratio(n) >= 3.5
    assert CompressionSpec(dtype="int4").ratio(n) >= 7.0
    assert CompressionSpec(dtype="fp8").ratio(n) >= 3.5
    # per-tensor scale amortizes to ~4x / ~8x
    assert CompressionSpec(dtype="int8", block=None).ratio(n) >= 3.9
    # tiny payloads never claim negative/absurd wins
    assert CompressionSpec(dtype="int8").wire_bytes(0) == 0
    assert CompressionSpec(dtype="int8").ratio(0) == 1.0
    for d in cx.DTYPES:
        spec = CompressionSpec(dtype=d)
        for m in (1, 255, 256, 257, 1000):
            assert spec.wire_bytes(m) > 0


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="dtype"):
        CompressionSpec(dtype="int9")
    with pytest.raises(ValueError, match="scope"):
        CompressionSpec(scope="pods")
    with pytest.raises(ValueError, match="reduce"):
        CompressionSpec(reduce="sum")
    with pytest.raises(ValueError, match="qsum"):
        CompressionSpec(dtype="fp8", reduce="qsum")
    with pytest.raises(ValueError, match="even"):
        CompressionSpec(dtype="int4", block=7)
    with pytest.raises(ValueError, match="positive"):
        CompressionSpec(block=-4)
    # aliases normalize instead of failing
    assert CompressionSpec(dtype="fp8-e4m3").dtype == "fp8"
    assert CompressionSpec(scope="cross-pod-only").scope == "cross-pod"


# ------------------------------------------------------------ flag grammar


def test_from_flag_grammar_accepts():
    s = CommSpec.from_flag("tree_int8")
    assert s.allreduce == "tree" and s.compression.dtype == "int8"
    assert s.compression.scope == "cross-pod"
    assert not s.compression.error_feedback and not s.overlap
    s = CommSpec.from_flag("hier_fp8_all")
    assert s.allreduce == "hier" and s.compression.scope == "all"
    s = CommSpec.from_flag("tree_int4_ef_overlap")
    assert s.compression.error_feedback and s.overlap
    s = CommSpec.from_flag("tree_int8_all_ef_overlap")
    assert (s.compression.scope == "all" and s.compression.error_feedback
            and s.overlap)
    # the alias keeps its historical identity when unmodified...
    s = CommSpec.from_flag("hier_int8")
    assert s.allreduce == "hier_int8" and s.compression is None
    # ...and decomposes to hier + the legacy spec when modified
    s = CommSpec.from_flag("hier_int8_ef")
    assert s.allreduce == "hier"
    assert s.compression.error_feedback and s.compression.reduce == "qsum"
    assert s.compression.block is None
    # plain transports still parse
    assert CommSpec.from_flag("tree_overlap").overlap
    assert CommSpec.from_flag("native").compression is None


def test_from_flag_grammar_rejects():
    for bad in ("tree_overlapp", "tree_ef", "hier_all", "bogus_int8",
                "tree_int9", "int8", "tree__int8", "hier_int8_fp8"):
        with pytest.raises(ValueError, match="comms flag"):
            CommSpec.from_flag(bad)
    with pytest.raises(ValueError, match="auto"):
        CommSpec.from_flag("auto")


# --------------------------------------------------- multi-device behavior

EQUIV = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import CommSpec, Communicator, CompressionSpec
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(2, 2, pod=2)
axes = ("pod", "data")
spec = P(tuple(mesh.axis_names))
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (8, 64), jnp.float32) * 3.0
TOL = {"int8": 0.05, "fp8": 0.05, "int4": 0.2}
for tname in ("tree", "hier", "native"):
    exact = None
    for dtype in (None, "int8", "fp8", "int4"):
        cs = CommSpec.from_flag(tname)
        if dtype is not None:
            cs = dataclasses.replace(cs, compression=CompressionSpec(
                dtype=dtype, scope="cross-pod"))
        comm = Communicator(mesh, cs, axes=axes)
        f = jax.jit(comm.wrap(comm.allreduce, in_specs=(spec,),
                              out_specs=spec))
        out = np.asarray(f(x))
        if dtype is None:
            exact = out
            continue
        rel = np.max(np.abs(out - exact)) / max(np.max(np.abs(exact)), 1e-9)
        assert rel < TOL[dtype], (tname, dtype, rel)
        # scope='all' also converges (coarser: every leg quantizes)
        ca = dataclasses.replace(cs, compression=dataclasses.replace(
            cs.compression, scope="all"))
        fa = jax.jit(Communicator(mesh, ca, axes=axes).wrap(
            Communicator(mesh, ca, axes=axes).allreduce,
            in_specs=(spec,), out_specs=spec))
        rel = (np.max(np.abs(np.asarray(fa(x)) - exact))
               / max(np.max(np.abs(exact)), 1e-9))
        assert rel < 3 * TOL[dtype], (tname, dtype, "all", rel)
print("OK")
"""


def test_compressed_allreduce_matches_exact_8dev():
    assert "OK" in run_py(EQUIV, ndev=8)


ALIAS_BITWISE = """
import jax, jax.numpy as jnp, numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from repro.comms import CommSpec, Communicator
from repro.comms.compat import shard_map
from repro.comms.topology import Topology
from repro.core import collectives as coll
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(2, 2, pod=2)
topo = Topology.from_mesh(mesh, axes=("pod", "data"))  # = the Communicator's
pod, in_axes = topo.pod_axis, topo.in_axes
spec = P(tuple(mesh.axis_names))
key = jax.random.PRNGKey(3)
x = jax.random.normal(key, (8, 64), jnp.float32) * 5.0

comm = Communicator(mesh, CommSpec.from_flag("hier_int8"),
                    axes=("pod", "data"))
got = np.asarray(jax.jit(comm.wrap(
    comm.allreduce, in_specs=(spec,), out_specs=spec))(x))

# the pre-refactor HierInt8Transport, op for op: in-pod reduce-scatter,
# pmax-shared per-tensor scale, exact int32 cross-pod psum, all-gather
def legacy(a):
    shape = a.shape
    flat = a.reshape(-1)
    n_in = 1
    for ax in in_axes:
        n_in *= lax.psum(1, ax)
    shard = coll._psum_scatter(flat.reshape(n_in, -1), tuple(in_axes))
    scale = jnp.maximum(jnp.max(jnp.abs(shard)), 1e-8) / 127.0
    scale = lax.pmax(scale, pod)
    q = jnp.clip(jnp.round(shard / scale), -127, 127).astype(jnp.int32)
    shard = lax.psum(q, pod).astype(shard.dtype) * scale
    out = coll._all_gather(shard, tuple(in_axes))
    return out.reshape(shape)

want = np.asarray(jax.jit(shard_map(
    legacy, mesh=mesh, in_specs=(spec,), out_specs=spec))(x))
assert np.array_equal(got, want), np.max(np.abs(got - want))
print("OK")
"""


def test_hier_int8_alias_bitwise_matches_legacy_8dev():
    assert "OK" in run_py(ALIAS_BITWISE, ndev=8)
