"""MoE: shard_map expert-parallel dispatch vs the dense oracle."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.mesh import make_local_mesh
from repro.models.moe import moe_ffn, moe_ffn_reference, moe_init
from tests._subproc import run_py

KEY = jax.random.PRNGKey(2)


def test_scatter_matches_reference_single_device():
    B, T, D, F, E, k = 2, 8, 16, 32, 4, 2
    p = moe_init(KEY, D, F, E)
    x = jax.random.normal(KEY, (B, T, D), jnp.bfloat16)
    mesh = make_local_mesh(1, 1)
    # capacity_factor high enough that nothing drops
    y, aux = moe_ffn(p, x, top_k=k, num_experts=E, capacity_factor=float(E),
                     mesh=mesh, batch_axes=("data",), mode="scatter")
    y_ref, aux_ref = moe_ffn_reference(p, x, top_k=k, num_experts=E)
    assert jnp.allclose(y.astype(jnp.float32), y_ref.astype(jnp.float32),
                        atol=0.05)
    assert jnp.allclose(aux, aux_ref, rtol=1e-3)


def test_replicated_matches_reference_single_device():
    B, T, D, F, E, k = 2, 1, 16, 32, 4, 2
    p = moe_init(KEY, D, F, E)
    x = jax.random.normal(KEY, (B, T, D), jnp.bfloat16)
    mesh = make_local_mesh(1, 1)
    y, _ = moe_ffn(p, x, top_k=k, num_experts=E, capacity_factor=4.0,
                   mesh=mesh, batch_axes=("data",), mode="replicated")
    y_ref, _ = moe_ffn_reference(p, x, top_k=k, num_experts=E)
    assert jnp.allclose(y.astype(jnp.float32), y_ref.astype(jnp.float32),
                        atol=0.05)


MULTIDEV = """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_local_mesh
from repro.models.moe import moe_ffn, moe_ffn_reference, moe_init
key = jax.random.PRNGKey(2)
B, T, D, F, E, k = 2, 8, 16, 32, 8, 2
p = moe_init(key, D, F, E)
x = jax.random.normal(key, (B, T, D), jnp.bfloat16)
mesh = make_local_mesh(2, 4)
y_ref, _ = moe_ffn_reference(p, x, top_k=k, num_experts=E)
for mode, t in (("scatter", 8), ("replicated", 1)):
    xx = x[:, :t]
    y, _ = moe_ffn(p, xx, top_k=k, num_experts=E, capacity_factor=float(E),
                   mesh=mesh, batch_axes=("data",), mode=mode)
    assert np.allclose(np.asarray(y, np.float32),
                       np.asarray(y_ref[:, :t], np.float32), atol=0.05), mode
# int8 expert gather stays close to bf16 (weight-only quantization)
y8, _ = moe_ffn(p, x, top_k=k, num_experts=E, capacity_factor=float(E),
                mesh=mesh, batch_axes=("data",), mode="scatter",
                fsdp_axes=("data",), gather_dtype="int8")
yb, _ = moe_ffn(p, x, top_k=k, num_experts=E, capacity_factor=float(E),
                mesh=mesh, batch_axes=("data",), mode="scatter",
                fsdp_axes=("data",))
err = np.max(np.abs(np.asarray(y8, np.float32) - np.asarray(yb, np.float32)))
rng = np.max(np.abs(np.asarray(yb, np.float32))) + 1e-6
assert err / rng < 0.05, f"int8 gather error {err/rng}"
print("OK")
"""


def test_expert_parallel_multidevice():
    assert "OK" in run_py(MULTIDEV, ndev=8)
