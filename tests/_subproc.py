"""Run a snippet in a subprocess with N virtual devices (multi-device
tests must not pollute the main pytest process, which stays at 1 device
per the dry-run isolation rule)."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, ndev: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), env.get("PYTHONPATH", "")])
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout
