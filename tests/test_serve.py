"""Serving subsystem: block-pool accounting, scheduler tick planning,
and engine end-to-end behaviour — paged == dense bit-for-bit at
temperature 0, staggered admission == solo greedy, truncation is
reported (never silent), EOS completion, load guards, the legacy path
for recurrent architectures, and the multi-rank drain barrier (in a
2-device subprocess).

Single-device engine tests run in-process and share module-scoped
engines so each dispatch width compiles once.
"""
import numpy as np
import pytest

from tests._subproc import run_py

# ------------------------------------------------------------- pool (pure)


def test_block_pool_accounting():
    from repro.serve import BlockPool, PoolExhausted

    pool = BlockPool(num_blocks=8, block_size=4, slots=3, max_len=16)
    assert pool.max_blocks_per_slot == 4
    assert pool.blocks_for(0) == 0 and pool.blocks_for(1) == 1
    assert pool.blocks_for(4) == 1 and pool.blocks_for(5) == 2

    pool.reserve(0, 9)                    # worst case: 3 blocks committed
    assert pool.committed == 3 and pool.used_blocks == 0
    with pytest.raises(ValueError):
        pool.reserve(0, 4)                # double-reserve is a bug

    pool.ensure(0, 5)                     # lease on demand: 2 of 3
    assert pool.used_blocks == 2 and pool.high_water == 2 and pool.dirty
    assert (pool.table[0, :2] >= 0).all() and pool.table[0, 2] == -1
    with pytest.raises(PoolExhausted):
        pool.ensure(0, 13)                # beyond the slot's commitment

    pool.reserve(1, 16)                   # 3 + 4 = 7 of 8
    assert not pool.can_reserve(16) and pool.can_reserve(4)
    with pytest.raises(PoolExhausted):
        pool.reserve(2, 16)               # would overcommit the pool

    pool.release(0)
    assert pool.committed == 4 and pool.used_blocks == 0
    assert (pool.table[0] == -1).all()
    assert pool.high_water == 2           # peak footprint is sticky

    with pytest.raises(ValueError):
        BlockPool(num_blocks=0, block_size=4, slots=1, max_len=16)


# -------------------------------------------------------- scheduler (pure)


def test_scheduler_conservative_ticks():
    from repro.serve import Scheduler

    sched = Scheduler(slots=2, chunk=4)
    st = sched.assign(0, rid=7, prompt=np.arange(6), cap=2,
                      temperature=0.0, eos_id=None)

    p1 = sched.plan()                     # first prefill chunk, full width
    assert p1.kind == "chunk" and p1.width == 4
    assert list(p1.lengths) == [4, 0] and list(p1.starts) == [0, 0]
    assert not p1.samples and not p1.use_next.any() and st.fed == 4

    p2 = sched.plan()                     # tail chunk completes -> samples
    assert list(p2.lengths) == [2, 0] and p2.starts[0] == 4
    assert p2.samples == [(0, st.epoch, 0)] and st.sampled == 1

    p3 = sched.plan()                     # decode ticks are width 1
    assert p3.kind == "decode" and p3.width == 1
    assert p3.use_next[0] and p3.samples == [(0, st.epoch, 1)]

    assert sched.plan() is None           # cap=2 dispatched; nothing left
    assert not sched.has_work()

    with pytest.raises(ValueError):
        Scheduler(slots=1, chunk=4, policy="nope")


def test_scheduler_mixed_packs_decode_into_chunks():
    from repro.serve import Scheduler

    sched = Scheduler(slots=2, chunk=4, policy="mixed")
    s0 = sched.assign(0, rid=0, prompt=np.arange(2), cap=3,
                      temperature=0.0, eos_id=None)
    sched.plan()                          # slot 0 finishes prefill
    assert s0.decode_ready
    s1 = sched.assign(1, rid=1, prompt=np.arange(6), cap=1,
                      temperature=0.0, eos_id=None)
    p = sched.plan()                      # decode row rides the chunk tick
    assert p.kind == "chunk"
    assert list(p.lengths) == [1, 4] and list(p.use_next) == [True, False]
    assert (0, s0.epoch, 1) in p.samples and s1.prefilling


# ------------------------------------------------- engine (1 device, jax)


@pytest.fixture(scope="module")
def stack():
    import jax
    from repro.configs.base import get_config, reduced
    from repro.launch.mesh import mesh_for_devices
    from repro.models.model import Model

    cfg = reduced(get_config("gemma3-4b"))
    mesh = mesh_for_devices(1)
    params = Model(cfg, mesh).init(jax.random.PRNGKey(0))
    return cfg, mesh, params


def _engine(stack, **kw):
    from repro.serve import Engine

    cfg, mesh, params = stack
    kw.setdefault("slots", 3)
    kw.setdefault("max_len", 32)
    kw.setdefault("block_size", 8)
    eng = Engine(cfg, mesh, **kw)
    eng.load(params)
    return eng


@pytest.fixture(scope="module")
def paged_engine(stack):
    return _engine(stack, cache_mode="paged")


@pytest.fixture(scope="module")
def dense_engine(stack):
    return _engine(stack, cache_mode="dense")


@pytest.fixture(scope="module")
def solo_engine(stack):
    return _engine(stack, slots=1, cache_mode="paged")


def _reqs(stack, lens=(5, 9, 3, 7), new=4, **kw):
    from repro.serve import Request

    cfg = stack[0]
    rng = np.random.default_rng(1)
    return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=n),
                    max_new_tokens=new, **kw)
            for i, n in enumerate(lens)]


def test_paged_matches_dense_bitwise_and_memory(stack, paged_engine,
                                                dense_engine):
    res_p = paged_engine.run_to_completion(_reqs(stack))
    res_d = dense_engine.run_to_completion(_reqs(stack))
    assert not res_p.truncated and not res_d.truncated
    assert sorted(res_p) == sorted(res_d) == [0, 1, 2, 3]
    for rid in res_p:                     # greedy: bit-for-bit identical
        assert res_p[rid] == res_d[rid] and len(res_p[rid]) == 4
        m = res_p.metrics[rid]
        assert m["ttft_s"] is not None and m["tokens"] == 4
        assert m["done_s"] >= m["ttft_s"] >= 0.0

    # paged footprint is proportional to live tokens, not slots*max_len
    pool = paged_engine.pool
    assert pool.used_blocks == 0          # drained
    assert 0 < pool.high_water * pool.block_size < \
        paged_engine.slots * paged_engine.max_len
    assert dense_engine.pool is None


def test_staggered_admission_matches_solo_greedy(stack, paged_engine,
                                                 solo_engine):
    solo = {}
    for r in _reqs(stack, lens=(5, 9, 3)):
        solo[r.rid] = solo_engine.run_to_completion([r])[r.rid]

    reqs = _reqs(stack, lens=(5, 9, 3))
    assert paged_engine.admit(reqs[0])
    for _ in range(2):
        paged_engine.step()               # r0 mid-flight when r1 arrives
    assert paged_engine.admit(reqs[1])
    paged_engine.step()
    assert paged_engine.admit(reqs[2])
    while paged_engine.sched.has_work():
        paged_engine.step()
    for r in reqs:
        assert r.out_tokens == solo[r.rid], r.rid


def test_eos_stops_generation(stack, paged_engine, solo_engine):
    base = solo_engine.run_to_completion(_reqs(stack, lens=(6,), new=6))[0]
    k = base.index(base[len(base) // 2])  # first occurrence of a mid token
    res = paged_engine.run_to_completion(
        _reqs(stack, lens=(6,), new=6, eos_id=base[k]))
    assert res[0] == base[:k + 1]
    assert res.metrics[0]["tokens"] == k + 1


def test_sampling_is_seeded_and_batched(stack, paged_engine):
    import jax

    def run(seed):
        paged_engine.key = jax.random.PRNGKey(seed)
        res = paged_engine.run_to_completion(
            _reqs(stack, lens=(5, 9), new=6, temperature=0.8))
        return [res[0], res[1]]

    a, b, c = run(3), run(3), run(4)
    assert a == b                         # same key -> same draws
    assert a != c                         # different key -> different draws
    assert all(len(t) == 6 for t in a)


def test_zero_cap_and_guards(stack, paged_engine):
    from repro.serve import Engine, Request

    cfg, mesh, _ = stack
    # prompt fills max_len minus nothing -> no generation budget
    res = paged_engine.run_to_completion(_reqs(stack, lens=(4,), new=0))
    assert res[0] == [] and res.metrics[0]["tokens"] == 0

    with pytest.raises(ValueError):       # prompt + 1 must fit max_len
        paged_engine.run_to_completion(_reqs(stack, lens=(32,)))

    cold = Engine(cfg, mesh, slots=1, max_len=32)
    with pytest.raises(RuntimeError, match="load"):
        cold.admit(Request(rid=0, prompt=np.arange(3)))
    with pytest.raises(RuntimeError, match="load"):
        cold.step()
    with pytest.raises(RuntimeError, match="load"):
        cold.run_to_completion([])


def test_never_admittable_request_rejected_up_front(stack):
    # pool smaller than one request's worst case: fail fast, don't spin
    eng = _engine(stack, slots=1, cache_mode="paged", num_blocks=1)
    with pytest.raises(ValueError, match="blocks"):
        eng.run_to_completion(_reqs(stack, lens=(9,)))


def test_truncation_is_reported_not_silent(stack, paged_engine):
    reqs = _reqs(stack, lens=(5, 9), new=6)
    res = paged_engine.run_to_completion(reqs, max_steps=2)
    assert res.truncated
    assert set(res.unfinished) == {0, 1} and not res
    while paged_engine.sched.has_work():  # drain for subsequent tests
        paged_engine.step()
    assert paged_engine.pool.used_blocks == 0


def test_legacy_path_serves_recurrent_arch():
    import jax
    from repro.configs.base import get_config, reduced
    from repro.launch.mesh import mesh_for_devices
    from repro.models.model import Model
    from repro.serve import Engine, Request

    cfg = reduced(get_config("xlstm-350m"))
    mesh = mesh_for_devices(1)
    with pytest.raises(ValueError, match="legacy"):
        Engine(cfg, mesh, slots=2, max_len=16, cache_mode="paged")

    eng = Engine(cfg, mesh, slots=2, max_len=16)   # auto -> legacy
    assert eng.cache_mode == "legacy" and eng.pool is None
    eng.load(Model(cfg, mesh).init(jax.random.PRNGKey(0)))
    rng = np.random.default_rng(2)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=n),
                    max_new_tokens=3) for i, n in enumerate((3, 5))]
    res = eng.run_to_completion(reqs)
    assert not res.truncated and sorted(res) == [0, 1]
    assert all(len(v) == 3 for v in res.values())


# ------------------------------------------------- multi-rank drain (2dev)

DRAIN = """
import numpy as np, jax
from repro.configs.base import get_config, reduced
from repro.launch.mesh import mesh_for_devices
from repro.models.model import Model
from repro.serve import Engine, Request, agree_admission_count

cfg = reduced(get_config("gemma3-4b"))
mesh = mesh_for_devices(2)
eng = Engine(cfg, mesh, slots=2, max_len=32, block_size=8)
assert eng.comm.size == 2
assert agree_admission_count(eng.comm, 3) == 3    # SPMD identity
eng.load(Model(cfg, mesh).init(jax.random.PRNGKey(0)))

rng = np.random.default_rng(0)
reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=n),
                max_new_tokens=3) for i, n in enumerate((5, 9, 3))]
res = eng.run_to_completion(reqs)                 # admission agreement +
assert not res.truncated and sorted(res) == [0, 1, 2]
# drain barrier: every rank idle, pool fully returned, no active slots
assert not eng.sched.active() and not eng.requests
assert eng.pool.used_blocks == 0 and eng.pool.committed == 0
eng.comm.sync()
print("OK", sorted(len(v) for v in res.values()))
"""


def test_multirank_drain_barrier_leaves_ranks_idle():
    out = run_py(DRAIN, ndev=2)
    assert "OK [3, 3, 3]" in out
