"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode
executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_chunk import mlstm_chunk
from repro.models.ssm import _mlstm_chunk

KEY = jax.random.PRNGKey(0)

FLASH_CASES = [
    # (Sq, Sk, Hq, Hkv, dh, window, dtype)
    (128, 128, 4, 2, 64, 0, jnp.float32),
    (256, 256, 8, 8, 128, 0, jnp.bfloat16),
    (256, 256, 4, 1, 64, 64, jnp.float32),
    (128, 128, 2, 2, 128, 32, jnp.bfloat16),
    (128, 128, 6, 3, 64, 0, jnp.float32),
    (64, 64, 2, 1, 128, 16, jnp.float32),
]


@pytest.mark.parametrize("Sq,Sk,Hq,Hkv,dh,win,dt", FLASH_CASES)
def test_flash_attention_vs_oracle(Sq, Sk, Hq, Hkv, dh, win, dt):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, Sq, Hq, dh), dt)
    k = jax.random.normal(ks[1], (2, Sk, Hkv, dh), dt)
    v = jax.random.normal(ks[2], (2, Sk, Hkv, dh), dt)
    out = flash_attention(q, k, v, causal=True, window=win,
                          block_q=64, block_k=64, interpret=True)
    exp = ref.attention_ref(q, k, v, causal=True, window=win)
    tol = 3e-2 if dt == jnp.bfloat16 else 3e-5
    assert jnp.max(jnp.abs(out.astype(jnp.float32)
                           - exp.astype(jnp.float32))) < tol


def test_flash_block_sizes():
    q = jax.random.normal(KEY, (1, 256, 4, 64))
    k = jax.random.normal(KEY, (1, 256, 4, 64))
    v = jax.random.normal(KEY, (1, 256, 4, 64))
    exp = ref.attention_ref(q, k, v, causal=True)
    for bq, bk in ((64, 128), (128, 64), (256, 256)):
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
        assert jnp.max(jnp.abs(out - exp)) < 3e-5, (bq, bk)


@pytest.mark.parametrize("S,dh,chunk", [(256, 64, 64), (128, 32, 32),
                                        (256, 128, 128)])
def test_mlstm_chunk_kernel_vs_oracle(S, dh, chunk):
    B, H = 2, 3
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, H, S, dh))
    k = jax.random.normal(ks[1], (B, H, S, dh))
    v = jax.random.normal(ks[2], (B, H, S, dh))
    li = jax.random.normal(ks[3], (B, H, S)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, S)) + 2)
    h_k, (C_k, n_k, m_k) = mlstm_chunk(q, k, v, li, lf, chunk=chunk,
                                       interpret=True)
    st = (jnp.zeros((B, H, dh, dh)), jnp.zeros((B, H, dh)),
          jnp.full((B, H), -jnp.inf))
    hs = []
    for c in range(S // chunk):
        sl = slice(c * chunk, (c + 1) * chunk)
        h_c, st = _mlstm_chunk(q[:, :, sl], k[:, :, sl], v[:, :, sl],
                               li[:, :, sl], lf[:, :, sl], st)
        hs.append(h_c)
    h_ref = jnp.concatenate(hs, axis=2)
    assert jnp.max(jnp.abs(h_k - h_ref)) < 1e-4
    assert jnp.max(jnp.abs(C_k - st[0])) < 1e-4
    assert jnp.max(jnp.abs(n_k - st[1])) < 1e-4
