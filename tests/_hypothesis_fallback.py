"""Minimal deterministic stand-in for `hypothesis`, used only when the
real package is not installed (tests/conftest.py wires it into
``sys.modules``).  It implements exactly the subset this suite uses —
``given``, ``settings``, and the ``integers / floats / lists /
sampled_from / composite / .map`` strategies — by drawing a fixed number
of pseudo-random examples from a seeded RNG, so the property tests stay
collected, running, and reproducible without the dependency.  Install
the real thing (requirements-dev.txt) for actual input-space search.
"""
from __future__ import annotations

import functools
import random
from typing import Any, Callable, List

DEFAULT_MAX_EXAMPLES = 25
_SEED = 0xC0FFEE


class Strategy:
    def __init__(self, sample: Callable[[random.Random], Any]):
        self._sample = sample

    def map(self, f: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng: f(self._sample(rng)))


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> Strategy:
    return Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements) -> Strategy:
    pool = list(elements)
    return Strategy(lambda rng: pool[rng.randrange(len(pool))])


def lists(elements: Strategy, *, min_size: int = 0,
          max_size: int = 10) -> Strategy:
    def sample(rng: random.Random) -> List[Any]:
        n = rng.randint(min_size, max_size)
        return [elements._sample(rng) for _ in range(n)]
    return Strategy(sample)


def composite(f: Callable) -> Callable[..., Strategy]:
    @functools.wraps(f)
    def build(*args, **kwargs) -> Strategy:
        return Strategy(lambda rng: f(
            lambda strat: strat._sample(rng), *args, **kwargs))
    return build


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored):
    """Decorator: records the example budget on the (given-wrapped)
    test; extra knobs like ``deadline`` are accepted and ignored."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strategies: Strategy):
    def deco(fn):
        # NOT functools.wraps: pytest must see a zero-arg signature, or
        # it would treat the property's parameters as fixtures
        def wrapper():
            rng = random.Random(_SEED)
            n = getattr(wrapper, "_fallback_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            for _ in range(n):
                drawn = [s._sample(rng) for s in strategies]
                fn(*drawn)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
