"""Data pipeline: determinism, restart reproducibility, prefetch order."""
import numpy as np

from repro.data.pipeline import DataConfig, Prefetcher, SyntheticTokens


def cfg():
    return DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=3)


def test_deterministic_across_instances():
    a = SyntheticTokens(cfg()).batch_at(17)
    b = SyntheticTokens(cfg()).batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_steps_differ_and_labels_shift():
    src = SyntheticTokens(cfg())
    b0, b1 = src.batch_at(0), src.batch_at(1)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # label[t] is the next token of the same stream
    assert b0["tokens"].shape == b0["labels"].shape == (8, 32)
    np.testing.assert_array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])


def test_restart_resumes_at_step():
    """Restarting the prefetcher at step k yields step k's batch — the
    checkpoint/restart contract."""
    src = SyntheticTokens(cfg())
    pf = Prefetcher(src, start_step=5)
    try:
        step, batch = pf.next()
        assert step == 5
        np.testing.assert_array_equal(np.asarray(batch["tokens"]),
                                      src.batch_at(5)["tokens"])
        step2, _ = pf.next()
        assert step2 == 6
    finally:
        pf.close()


def test_learnable_structure_present():
    """The repeated-ngram injection must create above-chance bigram
    repetition (otherwise the e2e train demo cannot reduce loss)."""
    b = SyntheticTokens(cfg()).batch_at(0)
    t = b["tokens"]
    n = DataConfig(vocab_size=1000, seq_len=32, global_batch=8).ngram
    repeats = (t[:, n:2 * n] == t[:, 0:n]).mean()
    assert repeats > 0.2
