"""The Communicator's PythonMPI surface on virtual devices:
send/recv round-trips, barrier, root!=0 broadcast/agg, and a
parametrized equivalence sweep asserting every registered transport
matches the native XLA collectives (subprocesses, 8 virtual CPUs)."""
import pytest

from tests._subproc import run_py

EQUIV = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import Communicator
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh({data}, {model}, pod={pod})
spec = P(tuple(mesh.axis_names))
v = jnp.arange(8 * 5, dtype=jnp.float32).reshape(8, 5) + 1
name = "{name}"
comm = Communicator(mesh, name)
native = Communicator(mesh, "native")
go = lambda c, f: c.run(f, v, in_specs=(spec,), out_specs=spec)

tol = dict(rtol=0.02, atol=0.5) if name == "hier_int8" else dict()
ref = go(native, lambda a: jax.lax.psum(a, native.axes))
assert np.allclose(go(comm, comm.allreduce), ref, **tol), "allreduce"

for root in (0, 5):
    b = go(comm, lambda a, r=root: comm.bcast(a, r))
    assert np.allclose(b, np.tile(np.asarray(v[root:root+1]), (8, 1))), \
        ("bcast", root)

for root in (0, 3):
    g = go(comm, lambda a, r=root: comm.agg(a, r).reshape(1, -1))
    got = np.asarray(g).reshape(8, 8, 5)
    assert np.allclose(got[root], np.asarray(v)), ("agg", root)
    zeros = [i for i in range(8) if i != root]
    assert np.allclose(got[zeros], 0), ("agg zeros", root)

for root in (0, 2):
    s = go(comm, lambda a, r=root: comm.scatter(a, r))
    exp = np.zeros(8, np.float32)
    exp[:5] = np.asarray(v[root])            # 5 elems pad to 8 ranks x 1
    assert np.allclose(np.asarray(s).reshape(-1), exp), ("scatter", root)

ag = go(comm, lambda a: comm.allgather(a).reshape(1, -1))
aga = np.asarray(ag).reshape(8, 8, 5)
assert all(np.allclose(aga[i], np.asarray(v)) for i in range(8)), "allgather"

rs = go(comm, lambda a: comm.reduce_scatter(a).reshape(1, -1))
flatsum = np.zeros(8, np.float32)
flatsum[:5] = np.asarray(v).sum(0)          # 5 elems pad to 8 ranks x 1
assert np.allclose(np.asarray(rs).reshape(-1), flatsum, **tol), "rs"
print("OK")
"""

TRANSPORTS = ("native", "tree", "serial", "hier", "hier_int8")


@pytest.mark.parametrize("name", TRANSPORTS)
def test_transport_matches_native_multi_pod(name):
    assert "OK" in run_py(EQUIV.format(name=name, data=2, model=2, pod=2))


@pytest.mark.parametrize("name", ("tree", "hier"))
def test_transport_matches_native_single_pod(name):
    assert "OK" in run_py(EQUIV.format(name=name, data=2, model=4, pod=0))


def test_send_recv_roundtrip_and_barrier():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.comms import Communicator
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(2, 2, pod=2)
spec = P(tuple(mesh.axis_names))
comm = Communicator(mesh)
v = jnp.arange(8 * 3, dtype=jnp.float32).reshape(8, 3)
go = lambda f: comm.run(f, v, in_specs=(spec,), out_specs=spec)

# SendMsg: rank 6 receives rank 1's payload, everyone else unchanged
y = np.asarray(go(lambda a: comm.send(a, dst=6, src=1)))
exp = np.asarray(v).copy(); exp[6] = np.asarray(v)[1]
assert np.allclose(y, exp), y

# round-trip: 1 -> 6 -> 1 restores 1's payload through rank 6
z = np.asarray(go(lambda a:
    comm.recv(comm.send(a, dst=6, src=1), 6, dst=1)))
exp2 = exp.copy(); exp2[1] = exp[6]
assert np.allclose(z, exp2), z

# a p2p round of disjoint pairs moves payloads independently
w = np.asarray(go(lambda a: comm.sendrecv(a, [(0, 7), (3, 2)])))
exp3 = np.asarray(v).copy(); exp3[7] = np.asarray(v)[0]
exp3[2] = np.asarray(v)[3]
assert np.allclose(w, exp3), w

# barrier: in-map token is all-zero; host-level sync returns
t = go(lambda a: a[:1] * 0 + comm.barrier())
assert np.allclose(t, 0)
comm.sync()
# pytree-awareness: dict payloads travel too
tree = {"a": v, "b": v * 2}
out = comm.run(lambda d: comm.send(d, dst=4, src=0), tree,
               in_specs=({"a": spec, "b": spec},),
               out_specs={"a": spec, "b": spec})
got = np.asarray(out["b"]); expb = np.asarray(v * 2).copy()
expb[4] = expb[0]
assert np.allclose(got, expb), got
print("OK")
"""
    assert "OK" in run_py(code)


def test_commspec_and_registry():
    from repro.comms import CommSpec, available_transports

    spec = CommSpec.from_flag("hier_int8")
    assert spec.allreduce == "hier_int8"
    assert spec.scatter == "hier_int8"
    with pytest.raises(ValueError):
        CommSpec.from_flag("auto")
    assert set(TRANSPORTS) <= set(available_transports())


