"""Property tests for the PGAS map index math (pure numpy — no devices).

Invariant: for ANY map (grid x dist x order x overlap x proc subset) and
array shape, scattering via storage_index_arrays then gathering via
global_index_arrays is the identity on the global array — i.e. the map
algebra is self-consistent, which is what makes redistribute-between-
any-two-maps correct by composition.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.dmap import Dmap

DISTS = [("b",), ("c",), ("bc", 2), ("bc", 3)]


@st.composite
def map_and_shape(draw):
    ndim = draw(st.integers(1, 3))
    grid = tuple(draw(st.sampled_from([1, 2, 4])) for _ in range(ndim))
    dist = tuple(draw(st.sampled_from(DISTS)) for _ in range(ndim))
    order = draw(st.sampled_from(["C", "F"]))
    overlap = tuple(draw(st.sampled_from([0, 1])) for _ in range(ndim))
    shape = tuple(draw(st.integers(g, 3 * g + 2)) for g in grid)
    n_ranks = int(np.prod(grid)) * draw(st.sampled_from([1, 2]))
    procs = tuple(range(int(np.prod(grid))))
    return Dmap(grid=grid, dist=dist, procs=procs, order=order,
                overlap=overlap), shape, n_ranks


def _roundtrip(dm: Dmap, shape, n_ranks) -> None:
    x = np.arange(int(np.prod(shape)), dtype=np.float64).reshape(shape)
    idx, valid = dm.storage_index_arrays(tuple(shape), n_ranks)
    storage = np.where(valid, x[tuple(idx)], 0.0)
    rank, locals_ = dm.global_index_arrays(tuple(shape))
    back = storage[(rank,) + tuple(locals_)]
    np.testing.assert_array_equal(back, x)


@settings(max_examples=60, deadline=None)
@given(map_and_shape())
def test_scatter_gather_roundtrip(ms):
    dm, shape, n_ranks = ms
    _roundtrip(dm, shape, n_ranks)


def test_fig1_map():
    """The paper's Fig 1 map: 2x2 grid, block, procs 0..3."""
    dm = Dmap(grid=(2, 2), procs=(0, 1, 2, 3))
    _roundtrip(dm, (4, 6), 4)
    # column-major ordering changes rank placement but not the roundtrip
    dmf = Dmap(grid=(2, 2), procs=(0, 1, 2, 3), order="F")
    _roundtrip(dmf, (4, 6), 4)
    c, l = dm._dim_map(4, 0)
    assert list(c) == [0, 0, 1, 1]


def test_subset_procs():
    dm = Dmap(grid=(2,), procs=(5, 2))
    _roundtrip(dm, (7,), 8)


def test_owner_semantics_cyclic():
    dm = Dmap(grid=(3,), dist=(("c",),))
    coord, local = dm._dim_map(7, 0)
    assert list(coord) == [0, 1, 2, 0, 1, 2, 0]
    assert list(local) == [0, 0, 0, 1, 1, 1, 2]


def test_validation():
    with pytest.raises(ValueError):
        Dmap(grid=(2,) * 5)
    with pytest.raises(ValueError):
        Dmap(grid=(2, 2), procs=(0, 1, 2))
    with pytest.raises(ValueError):
        Dmap(grid=(2,), order="X")
