"""Ring-cache invariants (hypothesis): after any chunked write pattern,
the cache holds exactly the last `window` positions with correct values."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models import cache as cl


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 4).map(lambda k: 2 ** k),       # W: 2..16
       st.integers(0, 3), st.integers(1, 6))
def test_ring_holds_last_window(w_exp, c_sel, n_chunks):
    W = w_exp
    C = [1, W, 2 * W, max(W // 2, 1)][c_sel]
    if C < W and W % C:
        C = 1
    B, H, dh = 2, 1, 2
    k = jnp.zeros((B, W, H, dh))
    v = jnp.zeros((B, W, H, dh))
    pos = jnp.full((B, W), -1, jnp.int32)
    total = 0
    for i in range(n_chunks):
        q_pos = jnp.broadcast_to(
            jnp.arange(total, total + C, dtype=jnp.int32)[None], (B, C))
        new_k = jnp.broadcast_to(
            q_pos[..., None, None].astype(jnp.float32), (B, C, H, dh))
        k, v, pos = cl.update_kv(k, v, pos, new_k, new_k, q_pos)
        total += C
    have = sorted(int(x) for x in np.asarray(pos[0]) if x >= 0)
    expect = list(range(max(0, total - W), total))
    assert have == expect
    # values match their positions
    flat_pos = np.asarray(pos[0])
    flat_val = np.asarray(k[0, :, 0, 0])
    for p, val in zip(flat_pos, flat_val):
        if p >= 0:
            assert val == float(p)


def test_cache_len_for():
    from repro.configs.base import GLOBAL_WINDOW
    assert cl.cache_len_for(GLOBAL_WINDOW, 100) == 100
    assert cl.cache_len_for(16, 100) == 16
    assert cl.cache_len_for(0, 100) == 100
