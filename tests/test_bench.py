"""The bench subsystem: registry round-trip (every case runs at tiny
sizes on 2 virtual devices), JSON artifact schema validation, and the
compare.py regression gate on synthetic baselines.  Only the round-trip
touches jax (in a subprocess); everything else is pure-python."""
import json

import pytest

from repro.bench import compare, registry, results
from tests._subproc import run_py

# ------------------------------------------------------ registry round-trip

ROUNDTRIP = """
import collections, json
from repro.bench import all_cases, results
from repro.bench.runner import run_cases_inline

names = [c.name for c in all_cases()]
rows = run_cases_inline(names, profile="tiny")
per_case = collections.Counter(r["case"] for r in rows)
missing = [n for n in names if not per_case[n]]
assert not missing, f"cases yielded no rows: {missing}"
assert len({r["name"] for r in rows}) == len(rows), "duplicate row names"

doc = results.new_document("tiny", rows, {n: 2 for n in names})
results.validate(doc)                       # emitted artifact is schema-valid
s = json.dumps(doc)
results.validate(json.loads(s))             # survives a JSON round-trip
assert any(r["measured"] for r in rows)
assert any(not r["measured"] for r in rows), "modeled rows missing"
print("OK", sorted(per_case))
"""


def test_registry_roundtrip_tiny_two_devices():
    out = run_py(ROUNDTRIP, ndev=2)
    assert "OK" in out
    for case in ("p2p", "agg", "bcast", "scatter", "grad_exchange",
                 "stream", "serving", "multipair", "bibw", "msgrate",
                 "overlap", "redistribute", "recovery", "compression"):
        assert case in out


def test_registry_metadata():
    cases = registry.all_cases()
    assert {c.name for c in cases} >= {"p2p", "agg", "bcast", "scatter",
                                       "grad_exchange", "stream", "serving",
                                       "multipair", "bibw", "msgrate",
                                       "overlap", "redistribute",
                                       "recovery", "compression"}
    for c in cases:
        assert c.ndev >= 1 and c.figure and c.description
    with pytest.raises(ValueError):
        registry.get_case("nope")
    with pytest.raises(ValueError):
        registry.get_profile("nope")
    # tiny budget must fit the 2-device test harness
    tiny = registry.get_profile("tiny")
    for c in cases:
        from repro.bench.runner import effective_ndev
        assert effective_ndev(c, tiny) <= 2


# ------------------------------------------------------- schema validation


def _row(name, median=100.0, measured=True, **over):
    r = {"name": name, "case": "p2p", "figure": "fig2/3",
         "transport": None, "ranks": 2, "size_bytes": 16,
         "measured": measured, "median_us": float(median),
         "p95_us": float(median), "min_us": float(median),
         "iters": 3, "warmup": 1, "gbps": None, "note": ""}
    r.update(over)
    return r


def _doc(rows, **over):
    d = {"schema": results.SCHEMA,
         "schema_version": results.SCHEMA_VERSION,
         "created_utc": "2026-01-01T00:00:00+00:00", "git_sha": "cafe",
         "jax_version": "0.0", "profile": "tiny",
         "device_counts": {"p2p": 2}, "rows": rows}
    d.update(over)
    return d


def test_validate_accepts_good_and_rejects_bad():
    results.validate(_doc([_row("a"), _row("b", measured=False)]))
    # schema v2 rate fields: absent, null, or non-negative numbers
    results.validate(_doc([_row("a", gbps=1.5, wire_gbps=0.4,
                                effective_gbps=1.5)]))
    bad = [
        _doc([_row("a", wire_gbps=-0.1)]),               # negative rate
        _doc([_row("a", effective_gbps=True)]),          # bool is not a rate
        _doc([_row("a", wire_gbps="fast")]),             # string rate
        _doc([_row("a", gbps=-1.0)]),
        _doc([_row("a")], schema="nope"),
        _doc([_row("a")], schema_version=99),
        _doc([]),                                        # empty rows
        _doc([_row("a"), _row("a")]),                    # duplicate name
        _doc([_row("a", median_us=-1.0)]),               # negative timing
        _doc([_row("a", min_us=500.0)]),                 # min > median
        _doc([_row("a", ranks="two")]),                  # wrong type
        _doc([_row("a", measured=1)]),                   # int is not bool
        _doc([_row("a")], device_counts={"p2p": "2"}),
    ]
    for doc in bad:
        with pytest.raises(ValueError):
            results.validate(doc)


def test_write_load_roundtrip(tmp_path):
    path = tmp_path / "BENCH_t.json"
    results.write(_doc([_row("a")]), str(path))
    doc = results.load(str(path))
    assert doc["rows"][0]["name"] == "a"


# -------------------------------------------------------- compare gating


def test_compare_pass_on_identical():
    doc = _doc([_row("a"), _row("b", 5000.0)])
    rep = compare.compare_docs(doc, doc)
    assert not rep["regressions"] and not rep["missing"] and not rep["new"]


def test_compare_flags_real_slowdown_only():
    base = _doc([_row("big", 5000.0), _row("small", 10.0),
                 _row("model", 5000.0, measured=False)])
    run = _doc([_row("big", 20000.0),        # 4x: regression
                _row("small", 40.0),         # 4x but under noise floor
                _row("model", 99999.0, measured=False)])  # modeled: ignored
    rep = compare.compare_docs(run, base, threshold=1.0,
                               noise_floor_us=100.0)
    assert [e["name"] for e in rep["regressions"]] == ["big"]
    # within-threshold jitter passes
    rep2 = compare.compare_docs(_doc([_row("big", 7000.0)]),
                                _doc([_row("big", 5000.0)]),
                                threshold=1.0, noise_floor_us=100.0)
    assert not rep2["regressions"]
    # symmetric speedups show up as improvements, never failures
    rep3 = compare.compare_docs(_doc([_row("big", 1000.0)]),
                                _doc([_row("big", 5000.0)]),
                                threshold=1.0, noise_floor_us=100.0)
    assert [e["name"] for e in rep3["improvements"]] == ["big"]


def test_merge_runs_requires_reproduced_slowdown():
    base = _doc([_row("a", 1000.0), _row("b", 1000.0)])
    spiked_a = _doc([_row("a", 20000.0), _row("b", 1000.0)])
    spiked_b = _doc([_row("a", 1000.0), _row("b", 20000.0)])
    # one-off stalls on different rows cancel out under best-of merge
    merged = compare.merge_runs([spiked_a, spiked_b])
    assert not compare.compare_docs(merged, base)["regressions"]
    # a slowdown present in every run survives the merge and fails
    merged2 = compare.merge_runs([spiked_a, spiked_a])
    rep = compare.compare_docs(merged2, base)
    assert [e["name"] for e in rep["regressions"]] == ["a"]
    # union semantics: rows missing from one run come from the other
    merged3 = compare.merge_runs([_doc([_row("a")]), _doc([_row("c")])])
    assert [r["name"] for r in merged3["rows"]] == ["a", "c"]


def test_compare_missing_and_new_rows_are_soft():
    base = _doc([_row("a"), _row("gone")])
    run = _doc([_row("a"), _row("fresh")])
    rep = compare.compare_docs(run, base)
    assert rep["missing"] == ["gone"] and rep["new"] == ["fresh"]
    assert not rep["regressions"]


def test_compare_cli_exit_codes(tmp_path):
    base_p = tmp_path / "base.json"
    run_p = tmp_path / "run.json"
    results.write(_doc([_row("a", 5000.0)]), str(base_p))
    results.write(_doc([_row("a", 5000.0)]), str(run_p))
    assert compare.main([str(run_p), str(base_p)]) == 0

    results.write(_doc([_row("a", 50000.0)]), str(run_p))
    assert compare.main([str(run_p), str(base_p)]) == 1
    assert compare.main([str(run_p), str(base_p), "--warn-only"]) == 0
    assert compare.main([str(run_p), str(base_p),
                         "--threshold", "100.0"]) == 0

    results.write(_doc([_row("other")]), str(run_p))
    assert compare.main([str(run_p), str(base_p)]) == 0
    assert compare.main([str(run_p), str(base_p),
                         "--strict-missing"]) == 1

    # malformed artifacts fail loudly, not silently pass the gate
    (tmp_path / "junk.json").write_text(json.dumps({"schema": "nope"}))
    with pytest.raises(ValueError):
        compare.main([str(tmp_path / "junk.json"), str(base_p)])


def test_committed_baseline_is_schema_valid():
    import os
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "benchmarks", "baseline.json")
    doc = results.load(path)
    cases = {r["case"] for r in doc["rows"]}
    assert {"p2p", "agg", "bcast", "scatter", "grad_exchange",
            "stream", "serving", "multipair", "bibw", "msgrate",
            "overlap", "redistribute", "recovery", "compression"} <= cases
    # acceptance tie-in: the baseline's overlap rows must show a positive
    # recovered fraction on at least two transports, and the overlapped
    # full train step must not be slower than blocking beyond the gate
    fracs = {}
    for r in doc["rows"]:
        if r["case"] == "overlap":
            f = float(r["note"].split()[0].split("=")[1])
            fracs.setdefault(r["transport"], []).append(f)
    pos = [t for t, fs in fracs.items() if any(f > 0 for f in fs)]
    assert len(pos) >= 2, fracs
    step = {r["name"]: r for r in doc["rows"]
            if r["name"].startswith("gradex_step_")}
    blk = step["gradex_step_blocking_tree"]["min_us"]
    ovl = step["gradex_step_overlap_tree"]["min_us"]
    # same criterion compare.py gates with: overlap counts as "no worse"
    # unless it exceeds the relative threshold AND the noise floor
    rel = (ovl - blk) / max(blk, 1e-9)
    assert rel <= compare.DEFAULT_THRESHOLD or \
        (ovl - blk) <= compare.DEFAULT_NOISE_FLOOR_US, (ovl, blk)
    # compression acceptance: at the largest swept size, wire bytes must
    # shrink >= 3.5x (int8/fp8) and >= 7x (int4) vs the logical float32
    # payload, and the compressed exchange must be no slower than the
    # uncompressed one on the same transport beyond the gate criterion
    comp = [r for r in doc["rows"] if r["case"] == "compression"]
    assert comp, "baseline is missing compression rows"
    top = max(r["size_bytes"] for r in comp)
    floors = {"int8": 3.5, "fp8": 3.5, "int4": 7.0}
    for r in comp:
        if r["size_bytes"] != top or r["name"].split("_")[2] == "none":
            continue
        dtype = r["name"].split("_")[2]
        ratio = r["effective_gbps"] / r["wire_gbps"]
        assert ratio >= floors[dtype], (r["name"], ratio)
        base_row = next(b for b in comp
                        if b["size_bytes"] == top
                        and b["transport"] == r["transport"]
                        and b["name"].split("_")[2] == "none")
        d_us = r["median_us"] - base_row["median_us"]
        rel = d_us / max(base_row["median_us"], 1e-9)
        assert rel <= compare.DEFAULT_THRESHOLD or \
            d_us <= compare.DEFAULT_NOISE_FLOOR_US, (r["name"], d_us)
