"""Unit tests for train-step helpers: microbatch sizing under assorted
mesh shapes and DDP-style gradient bucketing."""
import jax.numpy as jnp

from tests._subproc import run_py


def test_grad_bucket_indices_partition_leaves():
    """Buckets group by the first two tree-path entries and partition the
    flat leaf index set exactly."""
    from repro.train.steps import grad_bucket_indices

    tree = {
        "blocks": {"0": {"w": jnp.ones(2), "b": jnp.ones(1)},
                   "1": {"w": jnp.ones(3)}},
        "emb": {"table": jnp.ones(4)},
    }
    buckets = grad_bucket_indices(tree)
    flat_count = 4
    seen = sorted(i for b in buckets for i in b)
    assert seen == list(range(flat_count))           # exact partition
    # ('blocks','0') leaves share a bucket; ('blocks','1') and ('emb',*)
    # land elsewhere — 3 groups total
    assert len(buckets) == 3
    assert sorted(len(b) for b in buckets) == [1, 1, 2]


def test_effective_microbatches_edge_cases():
    code = """
import dataclasses
from repro.configs.base import get_config, reduced
from repro.launch.mesh import make_local_mesh
from repro.train.steps import effective_microbatches

cfg = reduced(get_config("h2o-danube-1.8b"), microbatches=4)

# single batch axis: (data=4, model=2) -> bprod=4
mesh = make_local_mesh(4, 2)
assert effective_microbatches(cfg, 16, mesh) == 4   # clean division
assert effective_microbatches(cfg, 64, mesh) == 4   # capped by cfg
# non-divisible global batch: 12/4 microbatches of 3 don't divide 4
# ranks, but 12/3 microbatches of 4 do
assert effective_microbatches(cfg, 12, mesh) == 3
# prime global batch: nothing divides, forced down to 1
assert effective_microbatches(cfg, 13, mesh) == 1
# global batch == rank count: one sample per rank, mb forced to 1
assert effective_microbatches(cfg, 4, mesh) == 1
# global batch below rank count: still clamps to 1 (never 0)
assert effective_microbatches(cfg, 2, mesh) == 1

# multi-axis batch mesh: (pod=2, data=2, model=2) -> bprod=4
mesh3 = make_local_mesh(2, 2, pod=2)
assert effective_microbatches(cfg, 16, mesh3) == 4
assert effective_microbatches(cfg, 8, mesh3) == 2

# 'replicate' strategy hands the model axis to the batch too: bprod=8
cfg_rep = dataclasses.replace(cfg, shard_strategy="replicate")
assert effective_microbatches(cfg_rep, 16, mesh3) == 2
print("OK")
"""
    assert "OK" in run_py(code, ndev=8, timeout=560)


# -------------------------------------------------- EF convergence tracking

# shared harness: run N steps of the real microbatched train step on the
# multi-pod mesh and return the per-step loss trajectory for one
# grad-comms mode (string flag or explicit CommSpec)
_CONV_HEADER = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.comms import CommSpec, CompressionSpec
from repro.configs.base import ShapeSpec, get_config, reduced
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.launch.mesh import make_local_mesh
from repro.models.model import Model
from repro.optim.optimizer import OptimizerConfig, opt_init
from repro.train import steps as steps_lib

STEPS = 24
cfg = reduced(get_config("h2o-danube-1.8b"), microbatches=2)
mesh = make_local_mesh(2, 2, pod=2)
model = Model(cfg, mesh)
# short warmup + a real lr: the default 100-step warmup would keep early
# updates tiny and hide any divergence inside numerical noise
ocfg = OptimizerConfig(total_steps=30, warmup_steps=2, peak_lr=3e-3)
shape = ShapeSpec("t", "train", 16, 32)
bundle = steps_lib.sharding_bundle(model, ocfg, shape)
data = SyntheticTokens(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  global_batch=32), mesh)
batches = [data.device_batch(s) for s in range(STEPS)]

def losses(mode):
    step_fn, _ = steps_lib.make_train_step(model, ocfg, shape.global_batch,
                                           grad_comms=mode)
    use_ef = steps_lib.flag_uses_ef(mode)
    shardings = (bundle["params"], bundle["opt"], bundle["input_shardings"],
                 NamedSharding(mesh, P()))
    if use_ef:
        ef_sh = steps_lib.ef_shardings(model)
        ef = steps_lib.ef_init(model)
        f = jax.jit(step_fn, in_shardings=shardings + (ef_sh,),
                    out_shardings=(bundle["params"], bundle["opt"], None,
                                   ef_sh))
    else:
        f = jax.jit(step_fn, in_shardings=shardings,
                    out_shardings=(bundle["params"], bundle["opt"], None))
    params = jax.jit(model.init, out_shardings=bundle["params"])(
        jax.random.PRNGKey(0))
    opt = jax.jit(lambda p: opt_init(ocfg, p),
                  out_shardings=bundle["opt"])(params)
    out = []
    for s in range(STEPS):
        step = jnp.asarray(s, jnp.int32)
        if use_ef:
            params, opt, m, ef = f(params, opt, batches[s], step, ef)
        else:
            params, opt, m = f(params, opt, batches[s], step)
        out.append(float(m["loss"]))
    return np.asarray(out)

auto = losses("auto")
"""


def test_ef_modes_track_exact_loss():
    """Every ``*_ef`` grad-comms mode must track the exact (GSPMD)
    trajectory within its recorded tolerance over >= 20 steps.  The
    bounds are ~4-50x the empirically recorded deviations (int8/fp8
    recorded <= 5e-3, int4 <= 2.4e-2 on this pinned setup), so they
    catch regressions to lossy-without-feedback behavior, not noise."""
    code = _CONV_HEADER + """
TOLS = {"tree_int8_ef": 0.02, "tree_fp8_ef": 0.02, "tree_int4_ef": 0.05,
        "hier_int8_ef": 0.02, "hier_fp8_ef": 0.02, "hier_int4_ef": 0.05}
for mode, tol in TOLS.items():
    dev = float(np.mean(np.abs(losses(mode) - auto)))
    assert dev <= tol, (mode, dev, tol)
    print(mode, round(dev, 5))
print("OK")
"""
    assert "OK" in run_py(code, ndev=8, timeout=560)


def test_error_feedback_beats_plain_quantization():
    """The load-bearing EF property: under aggressive compression
    (per-tensor int4 on EVERY leg), error feedback keeps the trajectory
    near exact while the same spec without feedback drifts past it —
    the threshold sits between the two recorded means (0.091 vs 0.144)."""
    code = _CONV_HEADER + """
base = CommSpec.from_flag("tree")
devs = {}
for ef in (True, False):
    cs = dataclasses.replace(base, compression=CompressionSpec(
        dtype="int4", block=None, scope="all", error_feedback=ef))
    devs[ef] = float(np.mean(np.abs(losses(cs) - auto)))
print("ef", round(devs[True], 5), "plain", round(devs[False], 5))
assert devs[True] < 0.115, devs
assert devs[False] > 0.115, devs
assert devs[True] < devs[False], devs
print("OK")
"""
    assert "OK" in run_py(code, ndev=8, timeout=560)
