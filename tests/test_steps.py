"""Unit tests for train-step helpers: microbatch sizing under assorted
mesh shapes and DDP-style gradient bucketing."""
import jax.numpy as jnp

from tests._subproc import run_py


def test_grad_bucket_indices_partition_leaves():
    """Buckets group by the first two tree-path entries and partition the
    flat leaf index set exactly."""
    from repro.train.steps import grad_bucket_indices

    tree = {
        "blocks": {"0": {"w": jnp.ones(2), "b": jnp.ones(1)},
                   "1": {"w": jnp.ones(3)}},
        "emb": {"table": jnp.ones(4)},
    }
    buckets = grad_bucket_indices(tree)
    flat_count = 4
    seen = sorted(i for b in buckets for i in b)
    assert seen == list(range(flat_count))           # exact partition
    # ('blocks','0') leaves share a bucket; ('blocks','1') and ('emb',*)
    # land elsewhere — 3 groups total
    assert len(buckets) == 3
    assert sorted(len(b) for b in buckets) == [1, 1, 2]


def test_effective_microbatches_edge_cases():
    code = """
import dataclasses
from repro.configs.base import get_config, reduced
from repro.launch.mesh import make_local_mesh
from repro.train.steps import effective_microbatches

cfg = reduced(get_config("h2o-danube-1.8b"), microbatches=4)

# single batch axis: (data=4, model=2) -> bprod=4
mesh = make_local_mesh(4, 2)
assert effective_microbatches(cfg, 16, mesh) == 4   # clean division
assert effective_microbatches(cfg, 64, mesh) == 4   # capped by cfg
# non-divisible global batch: 12/4 microbatches of 3 don't divide 4
# ranks, but 12/3 microbatches of 4 do
assert effective_microbatches(cfg, 12, mesh) == 3
# prime global batch: nothing divides, forced down to 1
assert effective_microbatches(cfg, 13, mesh) == 1
# global batch == rank count: one sample per rank, mb forced to 1
assert effective_microbatches(cfg, 4, mesh) == 1
# global batch below rank count: still clamps to 1 (never 0)
assert effective_microbatches(cfg, 2, mesh) == 1

# multi-axis batch mesh: (pod=2, data=2, model=2) -> bprod=4
mesh3 = make_local_mesh(2, 2, pod=2)
assert effective_microbatches(cfg, 16, mesh3) == 4
assert effective_microbatches(cfg, 8, mesh3) == 2

# 'replicate' strategy hands the model axis to the batch too: bprod=8
cfg_rep = dataclasses.replace(cfg, shard_strategy="replicate")
assert effective_microbatches(cfg_rep, 16, mesh3) == 2
print("OK")
"""
    assert "OK" in run_py(code, ndev=8, timeout=560)
